"""Cluster client: per-shard batching + pipelining over the §8.1 wire format.

The single-server :class:`~repro.core.dds_server.DDSClient` sends one network
message per call and blocks in ``wait``.  Serving heavy traffic needs the two
client-side techniques the paper's benchmark driver uses (§8.1):

  * **batching** — requests destined for the same shard are packed into one
    network message (``encode_batch``), so the traffic director runs its
    signature + predicate once per batch, not once per request;
  * **pipelining** — the client keeps issuing batches without waiting for
    responses; each shard's offload engine preserves per-connection request
    order (Fig 13), so responses stream back in issue order per shard.

Everything is cooperatively scheduled: ``pump()`` flushes pending batches,
steps every shard, and drains responses — tests can single-step the whole
cluster deterministically.
"""

from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import TYPE_CHECKING, Callable

from repro.core import wire
from repro.core.dds_server import (_OP_KIND, DDSStorageServer,
                                   drain_client_flow, encode_app_read,
                                   encode_app_write, encode_batch)
from repro.core.lifecycle import ClientLatency
from repro.core.traffic import FLAG_SYN, FiveTuple, Packet
from repro.core.vector import checksum64, scalar_mix

if TYPE_CHECKING:  # import cycle: distributed.cluster imports core
    from repro.distributed.cluster import DDSCluster


@dataclass
class ClientStats:
    requests: int = 0
    batches_sent: int = 0
    messages_sent: int = 0
    responses: int = 0
    timeouts: int = 0        # tick deadlines that expired unanswered
    resends: int = 0         # requests re-sent from replay notes
    dup_responses: int = 0   # stale/duplicate wire responses discarded


class ShardConnection:
    """One PEP-terminated flow to one shard (non-blocking enqueue/flush)."""

    def __init__(self, server: DDSStorageServer, ip: str, port: int,
                 tenant: int = 0):
        self.server = server
        self.flow = FiveTuple(ip, port, "10.0.0.1", server.config.server_port,
                              tenant=tenant)
        self._resp_flow = self.flow.reversed()
        self._seq = 1  # after SYN
        self._pending: list[bytes] = []
        self._rx = bytearray()
        self.arrival_order: list[int] = []   # req ids in response order
        # Ring epoch stamped on every sent packet (-1 = untagged: standalone
        # and unreplicated traffic skips the director's epoch fence).
        self.epoch = -1
        server.director.ingress.push(Packet(self.flow, 0, b"", flags=FLAG_SYN))
        server.signal()
        server.director.step()

    def enqueue(self, msg: bytes) -> None:
        self._pending.append(msg)

    def flush(self) -> int:
        """Pack all pending messages into ONE network message (batching)."""
        if not self._pending:
            return 0
        payload = encode_batch(self._pending)
        n = len(self._pending)
        self._pending.clear()
        pkt = Packet(self.flow, self._seq, payload, epoch=self.epoch)
        if self.server.director.stamp_checksums:
            pkt.csum = checksum64(payload)
        self.server.director.ingress.push(pkt)
        self._seq += len(payload)
        self.server.signal()   # client send: mark the target shard runnable
        return n

    def collect(self, responses: dict[int, tuple[int, bytes]]) -> int:
        """Drain OUR flow's packets; reassemble the segmented response stream.

        The director's ``to_client`` wire is demuxed per flow, so this is an
        O(1) swap of our own queue — other clients' traffic is never touched
        (the old shared wire forced a pop-and-requeue scan past every other
        client's packets on every drain)."""
        return drain_client_flow(self.server.director, self._resp_flow,
                                 self._rx, responses, self.arrival_order)


class ClusterClient:
    """Batched, pipelined client for a :class:`DDSCluster`.

    Requests are routed by cluster-global file id through the cluster's
    consistent-hash placement (``cluster.locate``); applications with their
    own keys (e.g. the KV store) route via ``send_raw(shard, build_msg)``.
    """

    _next_base_port = 40000          # distinct flows per client by default
    _port_lock = threading.Lock()

    def __init__(self, cluster: "DDSCluster", ip: str = "10.0.0.9",
                 port: int | None = None, tenant: int = 0,
                 retry_attempts: int = 0, timeout_ticks: int = 0):
        self.cluster = cluster
        self.tenant = tenant
        self._ip = ip
        if port is None:
            # Each client needs its own source ports, or two clients' flows
            # (and therefore their responses) become indistinguishable.
            with ClusterClient._port_lock:
                port = ClusterClient._next_base_port
                ClusterClient._next_base_port += len(cluster.servers)
        self.conns = [ShardConnection(srv, ip, port + i, tenant)
                      for i, srv in enumerate(cluster.servers)]
        # Failover/reshard awareness, armed on replicated or elastic
        # clusters: packets are epoch-tagged, issued requests keep a replay
        # note, and an epoch bump (failover promotion or resharding flip)
        # transparently re-routes everything parked on the old owner.
        # Plain clusters pay one attribute test per pump.
        self._armed = cluster.supervisor is not None or cluster.elastic
        self._epoch_seen = cluster.epoch
        epoch = cluster.epoch if self._armed else -1
        for conn in self.conns:
            conn.epoch = epoch
        # Shed retry (bounded exponential backoff honoring the server's
        # ``retry_after`` hint): 0 = surface E_SHED to the caller directly.
        self.retry_attempts = retry_attempts
        # Lossy-wire recovery: a request unanswered for ``timeout_ticks``
        # is re-sent from its replay note with doubled backoff (the
        # server-side dedup cache makes resends exactly-once).  0 = off.
        self.timeout_ticks = timeout_ticks
        self._deadlines: dict[int, tuple[int, int]] = {}  # rid -> (due, attempt)
        self._replay_on = (self._armed or retry_attempts > 0
                           or timeout_ticks > 0)
        # rid -> ("op", kind, gfid, offset, arg) for fid-addressed requests
        # (MUST re-encode at replay: the promoted shard's adopted copy has a
        # different local fid) or ("raw", shard, msg, cls) for application
        # messages (key-addressed; the bytes stay valid on the new shard).
        self._replay: dict[int, tuple] = {}
        self._retries: dict[int, int] = {}        # rid -> shed retry count
        self._redirects_seen: dict[int, int] = {}  # rid -> redirect replays
        self._backoff: list[tuple[int, int]] = []  # (due tick, rid)
        self._next_rid = 1
        self._rid_shard: dict[int, int] = {}
        self._outstanding = 0          # issued, response not yet collected
        # Per-shard issued-minus-collected counts: ``poll`` harvests ONLY
        # shards with outstanding requests, and ``flush`` visits only dirty
        # (buffered-but-unsent) connections — client-side mirrors of the
        # cluster's ready-set scheduling, so idle shards cost nothing.
        self._shard_outstanding = [0] * len(self.conns)
        self._dirty: list[int] = []    # shard indices with pending messages
        self._dirty_flag = [False] * len(self.conns)
        self._lock = threading.Lock()
        self.responses: dict[int, tuple[int, bytes]] = {}
        self.stats = ClientStats()
        # End-to-end tick latency: issue stamps per rid (reads and writes
        # in separate dicts — the class is known at issue, so the drain
        # pays one dict pop, no cross-object classification).  The
        # offloaded-vs-host split for reads lives in the server-side
        # lifecycle histograms, where it is exact.  The cluster's shared
        # clock makes deltas comparable across shards.
        self._issued_r: dict[int, int] = {}
        self._issued_w: dict[int, int] = {}
        self.latency = ClientLatency()
        self._lat_pos = [0] * len(self.conns)  # arrival_order scan cursors

    # -- request issue (buffered until the next flush/pump) -------------------------
    def _enqueue(self, shard: int, msg: bytes) -> None:
        self.conns[shard].enqueue(msg)
        if not self._dirty_flag[shard]:
            self._dirty_flag[shard] = True
            self._dirty.append(shard)

    def reserve_rids(self, shards: list[int], cls="r") -> list[int]:
        """Reserve one rid per target shard in ONE lock round.

        The shared bulk-issue path under :meth:`submit` and application
        burst clients (e.g. the KV store's ``submit``): rid range,
        outstanding counters and the rid->shard map are all updated in
        bulk, so a pipeline round of thousands of requests skips the
        per-call lock + dict churn.  ``cls`` picks the issue-tick stamp
        class for the end-to-end latency histograms: either one 'r'/'w'
        for the whole burst, or a per-op sequence for mixed batches."""
        n = len(shards)
        if shards and max(shards) >= len(self.conns):
            self._grow_conns()
        rid_shard = self._rid_shard
        with self._lock:
            # The per-shard counters gate response harvesting (poll skips
            # shards reading 0), so their updates stay under the client
            # lock — a lost `+= 1` against a concurrent poll() decrement
            # would park a shard with a response still queued.
            first = self._next_rid
            self._next_rid += n
            self._outstanding += n
            outs = self._shard_outstanding
            rids = list(range(first, first + n))
            for rid, shard in zip(rids, shards):
                rid_shard[rid] = shard
                outs[shard] += 1
        now = self.cluster.clock.now
        if isinstance(cls, str):
            issued = self._issued_r if cls == "r" else self._issued_w
            for rid in rids:
                issued[rid] = now
        else:
            ir, iw = self._issued_r, self._issued_w
            for rid, c in zip(rids, cls):
                (ir if c == "r" else iw)[rid] = now
        self.stats.requests += n
        return rids

    def _grow_conns(self) -> None:
        """The cluster grew (elastic ``add_shard``): open flows to the new
        shards.  Ports come from the GLOBAL allocator — extending this
        client's original contiguous block would collide with whichever
        client allocated the next block."""
        cl = self.cluster
        n = len(cl.servers)
        if n <= len(self.conns):
            return
        add = n - len(self.conns)
        with ClusterClient._port_lock:
            base = ClusterClient._next_base_port
            ClusterClient._next_base_port += add
        epoch = cl.epoch if self._armed else -1
        for i in range(add):
            conn = ShardConnection(cl.servers[len(self.conns)],
                                   self._ip, base + i, self.tenant)
            conn.epoch = epoch
            self.conns.append(conn)
            self._lat_pos.append(0)
        with self._lock:
            while len(self._shard_outstanding) < n:
                self._shard_outstanding.append(0)
            while len(self._dirty_flag) < n:
                self._dirty_flag.append(False)

    def _rid(self, shard: int, cls: str = "r") -> int:
        if shard >= len(self.conns):
            self._grow_conns()
        with self._lock:
            rid = self._next_rid
            self._next_rid += 1
            self._outstanding += 1
            self._shard_outstanding[shard] += 1
        self._rid_shard[rid] = shard
        issued = self._issued_r if cls == "r" else self._issued_w
        issued[rid] = self.cluster.clock.now
        self.stats.requests += 1
        return rid

    def _arm_timeout(self, rid: int) -> None:
        if self.timeout_ticks:
            self._deadlines[rid] = (self.cluster.clock.now
                                    + self.timeout_ticks, 0)

    def read(self, gfid: int, offset: int, nbytes: int) -> int:
        loc = self.cluster.locate(gfid)
        rid = self._rid(loc.shard)
        if self._replay_on:
            self._replay[rid] = ("op", "r", gfid, offset, nbytes)
            self._arm_timeout(rid)
        self._enqueue(loc.shard,
                      encode_app_read(rid, loc.local_fid, offset, nbytes))
        return rid

    # -- unified burst surface --------------------------------------------------------
    def submit(self, ops: list[tuple]) -> list[int]:
        """Issue a burst of operations; returns one handle (request id) per
        op, in order.  THE burst-issue surface — every legacy burst name
        (``read_many``/``write_many``/``wait_many``) is a thin deprecated
        wrapper over ``submit``/:meth:`harvest`.

        Ops are ``("r"|"read", gfid, offset, nbytes)`` or
        ``("w"|"write", gfid, offset, data)``; reads and writes mix freely
        in one batch (per-op latency classes ride the generalized
        :meth:`reserve_rids`).  The client's tenant binds once per
        connection and rides every flow — never passed per call.
        """
        locate = self.cluster.locate
        locs = []
        cls = []
        for op in ops:
            cls.append(_OP_KIND[op[0]])
            locs.append(locate(op[1]))
        rids = self.reserve_rids([loc.shard for loc in locs], cls)
        enqueue = self._enqueue
        replay = self._replay if self._replay_on else None
        for rid, loc, k, op in zip(rids, locs, cls, ops):
            if replay is not None:
                replay[rid] = ("op", k, op[1], op[2], op[3])
                self._arm_timeout(rid)
            if k == "r":
                enqueue(loc.shard,
                        encode_app_read(rid, loc.local_fid, op[2], op[3]))
            else:
                enqueue(loc.shard,
                        encode_app_write(rid, loc.local_fid, op[2], op[3]))
        return rids

    def read_many(self, reads: list[tuple[int, int, int]]) -> list[int]:
        """Deprecated: ``submit([("r", gfid, off, n), ...])``."""
        return self.submit([("r", gfid, offset, nbytes)
                            for gfid, offset, nbytes in reads])

    def write(self, gfid: int, offset: int, data: bytes) -> int:
        loc = self.cluster.locate(gfid)
        rid = self._rid(loc.shard, "w")
        if self._replay_on:
            self._replay[rid] = ("op", "w", gfid, offset, data)
            self._arm_timeout(rid)
        self._enqueue(loc.shard,
                      encode_app_write(rid, loc.local_fid, offset, data))
        return rid

    def write_many(self, writes: list[tuple[int, int, bytes]]) -> list[int]:
        """Deprecated: ``submit([("w", gfid, off, data), ...])``.

        Writes to one shard keep issue order, which the coalescing file
        service turns into adjacent scatter-gather runs."""
        return self.submit([("w", gfid, offset, data)
                            for gfid, offset, data in writes])

    def send_raw(self, shard: int, build_msg: Callable[[int], bytes],
                 cls: str = "r", key: bytes | None = None) -> int:
        """Route an application-defined message to an explicit shard.

        The shard is translated through the cluster's repair chain at
        issue time: after a failover the ring owner's route moves to the
        promoted replica and STAYS moved — even once the old primary
        heals and rejoins as a replica, sending to it directly would
        split-brain its application state.

        ``key`` (optional) is kept on the replay note: a resharding flip
        moves key OWNERSHIP without a failover, so a replay must re-hash
        the key against the current ring rather than follow the repair
        chain of the originally targeted shard."""
        shard = self.cluster.route_of(shard)
        rid = self._rid(shard, cls)
        msg = build_msg(rid)
        if self._replay_on:
            self._replay[rid] = ("raw", shard, msg, cls, key)
            self._arm_timeout(rid)
        self._enqueue(shard, msg)
        return rid

    def issue_many(self, shards: list[int],
                   build_msg: Callable[[int, int], bytes],
                   cls: str = "r", keys: list | None = None) -> list[int]:
        """Burst form of :meth:`send_raw`: the PUBLIC bulk-issue path for
        application clients (e.g. the KV store's ``get_many``).

        ``build_msg(rid, i)`` encodes the i-th message with its reserved
        request id.  One rid-range reservation covers the whole burst, and
        enqueueing stays inside this class so the dirty-connection and
        per-shard outstanding bookkeeping cannot be bypassed.  Target
        shards follow the cluster's repair chain (see :meth:`send_raw`);
        ``keys`` (optional, parallel to ``shards``) makes replays re-hash
        each key against the current ring (resharding flips)."""
        route_of = self.cluster.route_of
        shards = [route_of(s) for s in shards]
        rids = self.reserve_rids(shards, cls)
        enqueue = self._enqueue
        replay = self._replay if self._replay_on else None
        for i, (rid, shard) in enumerate(zip(rids, shards)):
            msg = build_msg(rid, i)
            if replay is not None:
                replay[rid] = ("raw", shard, msg,
                               cls if isinstance(cls, str) else cls[i],
                               keys[i] if keys is not None else None)
                self._arm_timeout(rid)
            enqueue(shard, msg)
        return rids

    # -- pipelined scheduling ---------------------------------------------------------
    def flush(self) -> int:
        """Send one batched message per DIRTY shard with buffered requests.

        Only connections that actually buffered messages since the last
        flush are visited (and their shards doorbell-signaled through
        ``ShardConnection.flush``) — on a 16-shard cluster with skewed
        traffic the old every-conn scan was pure idle cost."""
        if not self._dirty:
            return 0
        sent = 0
        dirty, self._dirty = self._dirty, []
        flags = self._dirty_flag
        for i in dirty:
            flags[i] = False
            n = self.conns[i].flush()
            if n:
                self.stats.batches_sent += 1
                self.stats.messages_sent += n
                sent += n
        return sent

    def pump(self) -> int:
        """One cooperative step: flush -> step every shard -> drain responses.

        On replicated clusters the step also reconciles failovers (a ring
        epoch bump re-routes and replays everything parked on the dead
        shard) and releases shed retries whose backoff expired."""
        work = self.flush()
        work += self.cluster.pump()
        if self._armed:
            work += self._sync_epoch()
        if self._backoff:
            work += self._pump_backoff()
        if self._deadlines:
            work += self._pump_timeouts()
        return work + self.poll()

    def poll(self) -> int:
        """Drain THIS client's responses without stepping the cluster.

        Harvests ONLY shards with outstanding requests (the per-shard
        issued-minus-collected counters): with several clients sharing a
        16-shard cluster, a client with traffic on two shards no longer
        peeks the other fourteen demuxed queues on every scheduling round.
        """
        responses = self.responses
        got = 0
        outs = self._shard_outstanding
        lat_pos = self._lat_pos
        collected: list[tuple[int, int]] = []
        rid_shard = self._rid_shard
        for i, conn in enumerate(self.conns):
            if not outs[i]:
                continue
            before = len(responses)
            conn.collect(responses)
            ao = conn.arrival_order
            if len(ao) > lat_pos[i]:
                # Exactly-once at the client: a resent request can be
                # answered twice (or a healed shard can flush a stale
                # ack).  A response whose rid is no longer booked was
                # already surfaced — discard it BEFORE the outstanding
                # accounting below, or the spurious decrement would park
                # a shard with responses still owed.
                # (pop, not del: a duplicated frame can land the same
                # rid twice in one drain window.)
                for rid in ao[lat_pos[i]:]:
                    if rid not in rid_shard and \
                            responses.pop(rid, None) is not None:
                        self.stats.dup_responses += 1
                self._record_latency(conn, ao, lat_pos[i])
                if len(ao) >= 1 << 16:
                    # Fully consumed: reset so a long-running client's
                    # arrival log cannot grow without bound.
                    conn.arrival_order = []
                    lat_pos[i] = 0
                else:
                    lat_pos[i] = len(ao)
            n = len(responses) - before
            if n:
                collected.append((i, n))
                got += n
        if got:
            # Decrement under the issue lock: `-=` is read-modify-write,
            # and racing a concurrent issuer's increment could lose one and
            # park the shard (poll would skip it forever).
            with self._lock:
                for i, n in collected:
                    outs[i] -= n
                self._outstanding -= got
            self.stats.responses += got
        return got

    def _record_latency(self, conn: ShardConnection, arrival_order: list,
                        pos: int) -> None:
        """End-to-end issue->drain ticks for newly arrived responses.

        Classified read/write from the issue-side stamp dicts (one pop on
        the common path); the offloaded-vs-host split for reads is exact in
        the serving shard's ``lifecycle`` histograms."""
        latency = self.latency
        now = self.cluster.clock.now
        wpop = self._issued_w.pop
        rpop = self._issued_r.pop
        radd = latency.hist_for("read").add
        wadd = latency.hist_for("write").add
        for rid in arrival_order[pos:]:
            t0 = rpop(rid, None)
            if t0 is not None:
                radd(now - t0)
                continue
            t0 = wpop(rid, None)
            if t0 is not None:
                wadd(now - t0)

    def _any_terminal(self) -> bool:
        """True iff any connected server holds a terminal mark."""
        conns = self.conns
        seen: set[int] = set()
        for conn in (conns.values() if hasattr(conns, "values") else conns):
            lc = conn.server.lifecycle
            if id(lc) in seen:
                continue
            seen.add(id(lc))
            if lc.has_terminal():
                return True
        return False

    def _check_terminal(self, rids) -> int:
        """Reconcile terminal server-side marks for ``rids``.

        A terminally marked request never gets a wire response; without
        this, ``wait`` and ``harvest`` would spin their whole iteration
        budget into a timeout heuristic.  Two mark kinds:

        ``E_SHED``
            Dropped under overload/admission — surfaced to the caller as a
            ``(E_SHED, hint)`` response (``harvest`` may then retry it
            under the bounded-backoff policy).

        ``E_REDIRECT``
            Refused by the epoch fence: the request was routed before a
            failover repaired the ring.  Replayed transparently against
            the repaired ring with the SAME request id (bounded per rid);
            surfaced terminally only past the cap or with no replay note.

        Each mark is reconciled against ITS OWN shard's outstanding counter
        exactly once — the rid->shard entry is consumed on surfacing, so a
        rid can never be double-decremented (or charged against another
        tenant's connection) even if callers probe it again."""
        found = 0
        responses = self.responses
        conns = self.conns
        rid_shard = self._rid_shard
        for rid in rids:
            shard = rid_shard.get(rid)
            if shard is None:
                continue
            conn = conns[shard]
            term = conn.server.lifecycle.take_terminal(conn.flow, rid)
            if term is None:
                continue
            code, hint = term
            if code == wire.E_REDIRECT:
                seen = self._redirects_seen.get(rid, 0)
                if rid in self._replay and seen < 8:
                    self._redirects_seen[rid] = seen + 1
                    self._sync_epoch()
                    if self._resubmit(rid):
                        found += 1
                        continue
                    continue  # _resubmit surfaced it terminally
            responses[rid] = (code, hint)
            rid_shard.pop(rid, None)
            self._issued_r.pop(rid, None)
            self._issued_w.pop(rid, None)
            with self._lock:
                self._shard_outstanding[shard] -= 1
                self._outstanding -= 1
            found += 1
        return found

    # -- failover reconciliation -------------------------------------------------------
    def _sync_epoch(self) -> int:
        """Adopt the cluster's ring epoch after a failover.

        Updates every connection's outgoing epoch tag and re-routes each
        unanswered request parked on a now-dead shard — the dead shard can
        never answer, so without this those rids would hang forever.
        Returns the number of requests moved (work, for pump loops)."""
        cur = self.cluster.epoch
        if cur == self._epoch_seen:
            return 0
        self._epoch_seen = cur
        self._grow_conns()   # an elastic add_shard bumps the epoch too
        for conn in self.conns:
            conn.epoch = cur
        dead = self.cluster._dead
        if not dead:
            return 0
        moved = 0
        responses = self.responses
        for rid, shard in list(self._rid_shard.items()):
            if shard not in dead or rid in responses:
                continue
            if self._resubmit(rid):
                moved += 1
        return moved

    def _replay_msg(self, rid: int, entry: tuple) -> tuple[int, bytes]:
        """Re-materialize a request against the CURRENT ring: fid-addressed
        ops re-encode (the promoted shard's adopted copy has a different
        local fid); raw application messages re-route by repaired shard."""
        if entry[0] == "op":
            _, kind, gfid, offset, arg = entry
            loc = self.cluster.locate(gfid)
            if kind == "r":
                return loc.shard, encode_app_read(rid, loc.local_fid,
                                                  offset, arg)
            return loc.shard, encode_app_write(rid, loc.local_fid,
                                               offset, arg)
        _, shard, msg, _cls, key = entry
        if key is not None:
            # Key-addressed: ownership may have MOVED at a resharding
            # flip — re-hash against the current ring (the repair chain
            # only tracks failover promotions, not migrations).
            return self.cluster.shard_for_key(key), msg
        return self.cluster.route_of(shard), msg

    def _resubmit(self, rid: int) -> bool:
        """Move a still-booked rid to its repaired shard and re-enqueue it.

        Counters stay booked (the request never surfaced); only the
        per-shard split moves.  A rid with no replay note — or whose
        repaired route is itself dead (unrecoverable group) — is surfaced
        terminally as ``(E_REDIRECT, current epoch)`` instead, so callers
        see a retryable error rather than a hang."""
        old = self._rid_shard.get(rid)
        if old is None:
            return False
        entry = self._replay.get(rid)
        shard = None
        if entry is not None:
            shard, msg = self._replay_msg(rid, entry)
        if shard is None or shard in self.cluster._dead:
            self.responses[rid] = (
                wire.E_REDIRECT, wire.encode_redirect_hint(self.cluster.epoch))
            self._rid_shard.pop(rid, None)
            self._issued_r.pop(rid, None)
            self._issued_w.pop(rid, None)
            with self._lock:
                self._shard_outstanding[old] -= 1
                self._outstanding -= 1
            return False
        if shard != old:
            with self._lock:
                self._shard_outstanding[old] -= 1
                self._shard_outstanding[shard] += 1
            self._rid_shard[rid] = shard
        self._enqueue(shard, msg)
        return True

    # -- shed retry with bounded exponential backoff ------------------------------------
    def _maybe_retry_shed(self, got: dict, pending: set) -> None:
        """Pull retryable E_SHED results back into ``pending``.

        Honors the server's ``retry_after`` hint scaled by an exponential
        per-attempt factor; after ``retry_attempts`` tries the E_SHED
        surfaces to the caller as the terminal answer."""
        if not self.retry_attempts:
            return
        for rid in list(got):
            code, hint = got[rid]
            if code != wire.E_SHED or rid not in self._replay:
                continue
            attempt = self._retries.get(rid, 0)
            if attempt >= self.retry_attempts:
                continue   # cap reached: surface the terminal error
            del got[rid]
            pending.add(rid)
            self._retries[rid] = attempt + 1
            _, retry_after = wire.decode_shed_hint(hint)
            # Deterministic per-rid jitter de-synchronizes retry storms:
            # without it every client shed in the same tick retries in
            # the same tick, re-colliding forever.  ``scalar_mix`` is a
            # pure function of (rid, attempt), so two same-seed runs
            # still pick identical deadlines.
            base = max(1, retry_after) << attempt
            jitter = scalar_mix(rid ^ (attempt << 56)) % base
            self._backoff.append(
                (self.cluster.clock.now + base + jitter, rid))

    def _pump_backoff(self) -> int:
        """Re-issue shed retries whose backoff deadline passed."""
        now = self.cluster.clock.now
        due = [rid for t, rid in self._backoff if t <= now]
        if not due:
            return 0
        self._backoff = [(t, rid) for t, rid in self._backoff if t > now]
        n = 0
        for rid in due:
            if self._rebook(rid):
                n += 1
        return n

    def _rebook(self, rid: int) -> bool:
        """Re-book a fully surfaced rid (counters were released when the
        E_SHED surfaced) and re-issue it along the repaired route."""
        entry = self._replay.get(rid)
        if entry is None:
            return False
        shard, msg = self._replay_msg(rid, entry)
        if shard in self.cluster._dead:
            return False
        cls = entry[1] if entry[0] == "op" else entry[3]
        with self._lock:
            self._outstanding += 1
            self._shard_outstanding[shard] += 1
        self._rid_shard[rid] = shard
        # Re-stamp the issue tick: the latency histogram records this
        # attempt's issue->drain, not time spent parked in backoff.
        issued = self._issued_r if cls == "r" else self._issued_w
        issued[rid] = self.cluster.clock.now
        self._arm_timeout(rid)   # re-booked requests regain loss protection
        self._enqueue(shard, msg)
        return True

    def _pump_timeouts(self) -> int:
        """Resend requests whose tick deadline expired unanswered.

        The resend re-materializes the request against the CURRENT ring
        (same request id — the server-side dedup cache suppresses the
        copy if the original survived, or replays the cached ack if only
        the ack was lost) and re-arms the deadline with doubled backoff.
        Deadlines for answered/surfaced rids are dropped lazily here."""
        now = self.cluster.clock.now
        due = [rid for rid, (t, _a) in self._deadlines.items() if t <= now]
        if not due:
            return 0
        n = 0
        tmo = self.timeout_ticks
        for rid in due:
            if rid in self.responses or rid not in self._rid_shard:
                self._deadlines.pop(rid, None)
                continue
            entry = self._replay.get(rid)
            if entry is None:
                self._deadlines.pop(rid, None)
                continue
            attempt = self._deadlines[rid][1]
            shard, msg = self._replay_msg(rid, entry)
            if shard in self.cluster._dead:
                # Repaired route still down: leave recovery to the
                # failover machinery, re-arm one plain window.
                self._deadlines[rid] = (now + tmo, attempt)
                continue
            old = self._rid_shard[rid]
            if shard != old:
                with self._lock:
                    self._shard_outstanding[old] -= 1
                    self._shard_outstanding[shard] += 1
                self._rid_shard[rid] = shard
            self._enqueue(shard, msg)
            self.stats.timeouts += 1
            self.stats.resends += 1
            self._deadlines[rid] = (now + (tmo << min(attempt + 1, 6)),
                                    attempt + 1)
            n += 1
        return n

    def _finalize(self, rid: int) -> None:
        """Drop replay/retry bookkeeping once a result reaches the caller."""
        self._replay.pop(rid, None)
        self._retries.pop(rid, None)
        self._redirects_seen.pop(rid, None)
        self._deadlines.pop(rid, None)

    def outstanding(self) -> int:
        """Issued-but-unanswered requests — an O(1) counter, not a dict scan."""
        return self._outstanding

    def _drain_busy_devices(self) -> None:
        """Settle device backlogs — only on shards whose device is busy
        (the old every-shard ``drain()`` was an idle-cost sweep)."""
        for srv in self.cluster.servers:
            if srv.device.busy():
                srv.device.drain()

    def run_until_idle(self, max_iters: int = 200_000) -> None:
        """Converge on ready-set emptiness + no outstanding requests.

        ``pump() == 0`` already certifies no shard is runnable or busy (the
        cluster verifies ``busy()`` on an empty ready set), so the common
        exit is a single zero-work round — no idle sweeps.  The bounded
        idle escape survives only for genuinely unanswerable requests
        (e.g. shed under overload)."""
        idle = 0
        for _ in range(max_iters):
            if self.pump():
                idle = 0
                continue
            if self.outstanding() == 0:
                return
            self._drain_busy_devices()
            # Reconcile terminal marks: a shed or epoch-refused request
            # will never produce wire work, so without this the
            # outstanding counters stay elevated forever and idle
            # convergence always burns the full 8-round escape hatch.
            if self._check_terminal(list(self._rid_shard)):
                continue
            if self._armed and any(s in self.cluster._dead
                                   for s in set(self._rid_shard.values())):
                # Requests parked on a crashed shard are not unanswerable —
                # the supervisor will promote a replica within its timeout;
                # keep pumping so detection and replay can run.
                idle = 0
                continue
            idle += 1
            if idle >= 8:
                return  # idle with requests genuinely unanswerable
        raise TimeoutError("cluster client did not go idle")

    # -- response access ----------------------------------------------------------------
    def wait(self, rid: int, max_iters: int = 200_000) -> tuple[int, bytes]:
        for _ in range(max_iters):
            if rid in self.responses:
                self._rid_shard.pop(rid, None)
                self._finalize(rid)
                return self.responses.pop(rid)
            if self.pump() == 0:
                self._drain_busy_devices()
                self._check_terminal((rid,))   # answered terminally
        raise TimeoutError(f"no response for request {rid}")

    def harvest(self, handles=None, block: bool = True,
                max_iters: int = 200_000) -> dict[int, tuple[int, bytes]]:
        """Collect responses: ``{handle: (status, body)}``.

        ``handles=None`` drains whatever has already arrived (one poll;
        never steps the cluster).  With explicit handles and ``block=True``
        this waits for ALL of them, harvesting whichever completes first:
        it pumps once per iteration while collecting every arrived handle —
        a serial per-handle ``wait`` loop would head-of-line block on the
        first one even when later handles (on other shards) had long
        completed.  Harvesting rides ``poll``'s outstanding-only scan, so
        only shards that still owe responses are touched.  On idle
        iterations, handles the servers marked SHED are answered terminally
        as ``(wire.E_SHED, hint)`` — a shed request can never produce a
        wire response, so waiting on a timeout heuristic would spin the
        whole iteration budget."""
        if handles is None:
            self.poll()
            out = dict(self.responses)
            rid_shard = self._rid_shard
            for rid in out:
                rid_shard.pop(rid, None)
                self._finalize(rid)
            self.responses.clear()
            return out
        got: dict[int, tuple[int, bytes]] = {}
        pending = set(handles)
        pending -= self._harvest(pending, got)
        if not block:
            self.poll()
            self._check_terminal(pending)
            pending -= self._harvest(pending, got)
            for rid in got:
                self._finalize(rid)
            return got
        for _ in range(max_iters):
            if not pending:
                for rid in got:
                    self._finalize(rid)
                return {rid: got[rid] for rid in handles}  # caller's order
            if self.pump() == 0:
                self._drain_busy_devices()
                self._check_terminal(pending)
            elif self._any_terminal():
                # Epoch-fence refusals can land while the cluster stays
                # busy for a long stretch (a live migration pumps work
                # through its whole cleanup grace).  Waiting for the
                # pump to go idle would stall the transparent replay
                # until retirement — reconcile as soon as any terminal
                # mark exists.  The probe is O(conns), so the common
                # no-terminal iteration stays cheap.
                self._check_terminal(pending)
            pending -= self._harvest(pending, got)
            if self.retry_attempts:
                self._maybe_retry_shed(got, pending)
        raise TimeoutError(f"no response for requests {sorted(pending)[:8]}...")

    def wait_many(self, rids: list[int],
                  max_iters: int = 200_000) -> dict[int, tuple[int, bytes]]:
        """Deprecated: ``harvest(rids)``."""
        return self.harvest(rids, max_iters=max_iters)

    def _harvest(self, pending: set[int],
                 got: dict[int, tuple[int, bytes]]) -> set[int]:
        """Move every already-answered rid out of ``self.responses``."""
        done = pending & self.responses.keys()
        for rid in done:
            got[rid] = self.responses.pop(rid)
            self._rid_shard.pop(rid, None)
        return done
