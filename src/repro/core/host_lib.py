"""DDS host front-end file library (§4.2).

A userspace library that storage applications link against instead of the OS
file system.  It offers a familiar file API — ``CreateDirectory``,
``CreateFile``, ``ReadFile``/``WriteFile`` (plus scattered reads & gathered
writes), ``CreatePoll``/``PollAdd``/``PollWait`` — while every operation is
encoded per Fig 9 and shipped to the DPU file service over the DMA rings of
§4.1.  All operations except ``PollWait`` are non-blocking.

``PollWait`` supports the paper's two modes:
  * non-blocking (``timeout_s=0``): returns immediately with whatever
    completions are available, letting the caller keep computing;
  * sleeping (``timeout_s>0``): the caller sleeps on an event that the "DPU
    driver interrupt" (fired by the file service after a response DMA-write)
    sets — zero CPU burned while waiting.
"""

from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Sequence

from repro.core import wire
from repro.core.file_service import FileServiceRunner
from repro.core.ring import ProgressiveRing, ResponseRing, frame, unframe_batch

INVALID_HANDLE = -1


@dataclass
class _Op:
    """Book-kept in its notification group until the completion is polled."""
    request_id: int
    op: int
    file_id: int
    offset: int
    nbytes: int
    scatter: Sequence[bytearray] | None = None  # destinations for scattered reads
    done: bool = False
    error: int = wire.E_PENDING
    data: bytes = b""


@dataclass
class Completion:
    request_id: int
    op: int
    file_id: int
    error: int
    nbytes: int
    data: bytes = b""


class NotificationGroup:
    """An epoll-like completion group with its own request/response rings."""

    def __init__(self, group_id: int, req_ring: ProgressiveRing,
                 resp_ring: ResponseRing):
        self.group_id = group_id
        self.req_ring = req_ring
        self.resp_ring = resp_ring
        self.files: set[int] = set()
        self._ops: dict[int, _Op] = {}
        self._lock = threading.Lock()
        self._event = threading.Event()  # set by the DPU driver interrupt
        self._next_rid = 1

    def interrupt(self) -> None:
        self._event.set()

    def book(self, op: _Op) -> None:
        with self._lock:
            self._ops[op.request_id] = op

    def next_request_id(self) -> int:
        with self._lock:
            rid = self._next_rid
            self._next_rid += 1
            return rid

    def _drain_ring(self) -> list[Completion]:
        got: list[Completion] = []
        while True:
            claimed = self.resp_ring.try_claim()
            if claimed is None:
                break
            _, raw = claimed
            for msg in unframe_batch(raw):
                resp = wire.decode_response(msg)
                with self._lock:
                    op = self._ops.pop(resp.request_id, None)
                if op is None:
                    continue  # response for an op another thread owns? (popped)
                data = resp.payload
                if op.op == wire.OP_READ and op.scatter is not None:
                    pos = 0  # scattered read: split into destination buffers
                    for buf in op.scatter:
                        n = min(len(buf), len(data) - pos)
                        buf[:n] = data[pos : pos + n]
                        pos += n
                got.append(Completion(resp.request_id, op.op, op.file_id,
                                      resp.error, resp.nbytes,
                                      data if op.scatter is None else b""))
        return got

    def poll_wait(self, timeout_s: float = 0.0) -> list[Completion]:
        comps = self._drain_ring()
        if comps or timeout_s == 0.0:
            return comps  # non-blocking mode
        # Sleeping mode: wait for the driver interrupt, no spinning.
        self._event.clear()
        deadline = timeout_s
        if self._event.wait(deadline):
            comps = self._drain_ring()
        return comps

    @property
    def outstanding(self) -> int:
        return len(self._ops)  # atomic len read; no lock on the poll path


class DDSFrontEnd:
    """The host file library.  One per storage application process."""

    def __init__(self, service: FileServiceRunner,
                 ring_capacity: int = 1 << 18,
                 max_progress: int | None = None):
        self.service = service
        self.ring_capacity = ring_capacity
        self.max_progress = max_progress
        self._groups: dict[int, NotificationGroup] = {}
        self._file_group: dict[int, int] = {}
        self._next_group = 1
        self._lock = threading.Lock()
        # A default control group for applications that never create one.
        self._control_group = self.create_poll()

    # -- notification groups -------------------------------------------------------
    def create_poll(self) -> int:
        with self._lock:
            gid = self._next_group
            self._next_group += 1
        req = ProgressiveRing(self.ring_capacity, self.max_progress,
                              name=f"req-g{gid}")
        resp = ResponseRing(self.ring_capacity, name=f"resp-g{gid}")
        group = NotificationGroup(gid, req, resp)
        # Rings are pre-registered to the DPU driver for DMA at creation time.
        self.service.register_group(gid, req, resp, interrupt=group.interrupt)
        with self._lock:
            self._groups[gid] = group
        return gid

    def poll_add(self, poll: int, file_handle: int) -> None:
        g = self._groups[poll]
        g.files.add(file_handle)
        self._file_group[file_handle] = poll

    def poll_wait(self, poll: int, timeout_s: float = 0.0) -> list[Completion]:
        return self._groups[poll].poll_wait(timeout_s)

    def any_outstanding(self) -> bool:
        """True while any notification group has un-polled operations."""
        for g in self._groups.values():
            if g.outstanding:
                return True
        return False

    # -- control plane ----------------------------------------------------------------
    def _sync_call(self, req: wire.Request) -> Completion:
        g = self._groups[self._control_group]
        req.request_id = g.next_request_id()
        g.book(_Op(req.request_id, req.op, req.file_id, req.offset, req.nbytes))
        g.req_ring.insert(frame(req.encode()))
        for _ in range(1_000_000):
            self.service.step()  # cooperative: drive the DPU when co-resident
            comps = g.poll_wait(0.0)
            if comps:
                return comps[0]
        raise TimeoutError("control op did not complete")

    def create_directory(self, name: str) -> int:
        c = self._sync_call(wire.Request(wire.OP_CREATE_DIR, 0, 0, 0, 0,
                                         name.encode()))
        if c.error != wire.E_OK:
            raise OSError(c.error, f"CreateDirectory({name})")
        return int.from_bytes(c.data[:4], "little")

    def create_file(self, name: str, directory: int = 0) -> int:
        c = self._sync_call(wire.Request(wire.OP_CREATE_FILE, 0, directory, 0, 0,
                                         name.encode()))
        if c.error != wire.E_OK:
            raise OSError(c.error, f"CreateFile({name})")
        return int.from_bytes(c.data[:4], "little")

    def delete_file(self, file_handle: int) -> None:
        c = self._sync_call(wire.Request(wire.OP_DELETE_FILE, 0, file_handle, 0, 0))
        if c.error != wire.E_OK:
            raise OSError(c.error, "DeleteFile")

    def fsync(self) -> None:
        c = self._sync_call(wire.Request(wire.OP_FSYNC, 0, 0, 0, 0))
        if c.error != wire.E_OK:
            raise OSError(c.error, "Fsync")

    # -- data plane (non-blocking) -------------------------------------------------
    def _group_for(self, file_handle: int) -> NotificationGroup:
        gid = self._file_group.get(file_handle, self._control_group)
        return self._groups[gid]

    def read_file(self, file_handle: int, offset: int, nbytes: int) -> int:
        """Non-blocking single read; returns the request id."""
        g = self._group_for(file_handle)
        rid = g.next_request_id()
        req = wire.Request(wire.OP_READ, rid, file_handle, offset, nbytes)
        g.book(_Op(rid, wire.OP_READ, file_handle, offset, nbytes))
        g.req_ring.insert(frame(req.encode()))
        return rid

    def read_file_scatter(self, file_handle: int, offset: int,
                          bufs: Sequence[bytearray]) -> int:
        """Scattered read: one file I/O, results split across ``bufs``."""
        g = self._group_for(file_handle)
        rid = g.next_request_id()
        total = sum(len(b) for b in bufs)
        req = wire.Request(wire.OP_READ, rid, file_handle, offset, total)
        g.book(_Op(rid, wire.OP_READ, file_handle, offset, total, scatter=bufs))
        g.req_ring.insert(frame(req.encode()))
        return rid

    def write_file(self, file_handle: int, offset: int, data: bytes) -> int:
        """Non-blocking single write; data inlined in the request (Fig 9)."""
        g = self._group_for(file_handle)
        rid = g.next_request_id()
        req = wire.Request(wire.OP_WRITE, rid, file_handle, offset,
                           len(data), bytes(data))
        g.book(_Op(rid, wire.OP_WRITE, file_handle, offset, len(data)))
        g.req_ring.insert(frame(req.encode()))
        return rid

    def write_file_gather(self, file_handle: int, offset: int,
                          bufs: Sequence[bytes]) -> int:
        """Gathered write: an array of source buffers, one file I/O."""
        return self.write_file(file_handle, offset, b"".join(bufs))

    # -- convenience synchronous wrappers (drive the co-resident service) ----------
    def _max_io(self, file_handle: int) -> int:
        """Largest single request: bounded by the ring's allowable progress
        (requests inline write data, Fig 9) and the response ring capacity."""
        g = self._group_for(file_handle)
        return min(g.req_ring.max_progress, g.resp_ring.capacity // 2) - 256

    def read_sync(self, file_handle: int, offset: int, nbytes: int) -> bytes:
        chunk = self._max_io(file_handle)
        parts = []
        for off in range(0, nbytes, chunk):
            n = min(chunk, nbytes - off)
            rid = self.read_file(file_handle, offset + off, n)
            parts.append(self._wait_one(file_handle, rid).data)
        return b"".join(parts)

    def write_sync(self, file_handle: int, offset: int, data: bytes) -> None:
        chunk = self._max_io(file_handle)
        for off in range(0, len(data), chunk):
            rid = self.write_file(file_handle, offset + off,
                                  data[off : off + chunk])
            c = self._wait_one(file_handle, rid)
            if c.error != wire.E_OK:
                raise OSError(c.error, "WriteFile")

    def _wait_one(self, file_handle: int, rid: int) -> Completion:
        g = self._group_for(file_handle)
        stash: list[Completion] = []
        for _ in range(1_000_000):
            self.service.step()
            for c in g.poll_wait(0.0):
                if c.request_id == rid:
                    if c.error != wire.E_OK and c.op == wire.OP_READ:
                        raise OSError(c.error, "ReadFile")
                    return c
                stash.append(c)
            self.service.fs.device.poll()
        raise TimeoutError(f"request {rid} did not complete")
