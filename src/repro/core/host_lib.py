"""DDS host front-end file library (§4.2).

A userspace library that storage applications link against instead of the OS
file system.  It offers a familiar file API — ``CreateDirectory``,
``CreateFile``, ``ReadFile``/``WriteFile`` (plus scattered reads & gathered
writes), ``CreatePoll``/``PollAdd``/``PollWait`` — while every operation is
encoded per Fig 9 and shipped to the DPU file service over the DMA rings of
§4.1.  All operations except ``PollWait`` are non-blocking.

``PollWait`` supports the paper's two modes:
  * non-blocking (``timeout_s=0``): returns immediately with whatever
    completions are available, letting the caller keep computing;
  * sleeping (``timeout_s>0``): the caller sleeps on an event that the "DPU
    driver interrupt" (fired by the file service after a response DMA-write)
    sets — zero CPU burned while waiting.
"""

from __future__ import annotations

import struct
import threading
from dataclasses import dataclass, field
from typing import Sequence

from repro.core import wire
from repro.core.file_service import FileServiceRunner
from repro.core.ring import (FRAME_HDR, ProgressiveRing, ResponseRing, frame,
                             unframe_batch)

INVALID_HANDLE = -1

# Frame length + request header, packed in ONE struct call ("<I" + "<BQIQI";
# little-endian structs concatenate without padding, so the fused bytes are
# identical to frame-then-header).  Guard the duplication: a change to
# either canonical struct must fail loudly here, not desync the wire.
_FRAMED_REQ = struct.Struct(FRAME_HDR.format + wire.REQ_HDR.format.lstrip("<"))
_REQ_SIZE = wire.REQ_HDR.size
assert _FRAMED_REQ.size == FRAME_HDR.size + _REQ_SIZE


@dataclass(slots=True)
class _Op:
    """Book-kept in its notification group until the completion is polled."""
    request_id: int
    op: int
    file_id: int
    offset: int
    nbytes: int
    scatter: Sequence[bytearray] | None = None  # destinations for scattered reads
    done: bool = False
    error: int = wire.E_PENDING
    data: bytes = b""


@dataclass(slots=True)
class Completion:
    request_id: int
    op: int
    file_id: int
    error: int
    nbytes: int
    data: bytes = b""


class NotificationGroup:
    """An epoll-like completion group with its own request/response rings."""

    def __init__(self, group_id: int, req_ring: ProgressiveRing,
                 resp_ring: ResponseRing):
        self.group_id = group_id
        self.req_ring = req_ring
        self.resp_ring = resp_ring
        self.files: set[int] = set()
        self._ops: dict[int, _Op] = {}
        self._lock = threading.Lock()
        self._event = threading.Event()  # set by the DPU driver interrupt
        self._next_rid = 1

    def interrupt(self) -> None:
        self._event.set()

    def book(self, op: _Op) -> None:
        with self._lock:
            self._ops[op.request_id] = op

    def next_request_id(self) -> int:
        with self._lock:
            rid = self._next_rid
            self._next_rid += 1
            return rid

    def next_request_ids(self, n: int) -> int:
        """Reserve ``n`` consecutive request ids in one lock round."""
        with self._lock:
            first = self._next_rid
            self._next_rid += n
            return first

    def book_many(self, ops: list[_Op]) -> None:
        with self._lock:
            book = self._ops
            for op in ops:
                book[op.request_id] = op

    def _drain_ring(self) -> list[Completion]:
        got: list[Completion] = []
        unpack = wire.RESP_HDR.unpack_from
        hdr = wire.RESP_HDR.size
        pop = self._ops.pop
        while True:
            claimed = self.resp_ring.try_claim()
            if claimed is None:
                break
            _, raw = claimed
            msgs = unframe_batch(raw)
            # One header unpack per message, ONE lock round per claimed
            # chunk to pop the whole batch's bookkeeping.
            heads = [unpack(m, 0) for m in msgs]
            with self._lock:
                ops = [pop(h[0], None) for h in heads]
            for (rid, err, nbytes), msg, op in zip(heads, msgs, ops):
                if op is None:
                    continue  # response for an op another thread owns? (popped)
                data = bytes(msg[hdr : hdr + nbytes]) if nbytes else b""
                if op.op == wire.OP_READ and op.scatter is not None:
                    pos = 0  # scattered read: split into destination buffers
                    for buf in op.scatter:
                        n = min(len(buf), len(data) - pos)
                        buf[:n] = data[pos : pos + n]
                        pos += n
                got.append(Completion(rid, op.op, op.file_id, err, nbytes,
                                      data if op.scatter is None else b""))
        return got

    def poll_wait(self, timeout_s: float = 0.0) -> list[Completion]:
        comps = self._drain_ring()
        if comps or timeout_s == 0.0:
            return comps  # non-blocking mode
        # Sleeping mode: wait for the driver interrupt, no spinning.
        self._event.clear()
        deadline = timeout_s
        if self._event.wait(deadline):
            comps = self._drain_ring()
        return comps

    def cancel(self, rid: int) -> bool:
        """Drop a booked op whose completion will never arrive (shed)."""
        with self._lock:
            return self._ops.pop(rid, None) is not None

    @property
    def outstanding(self) -> int:
        return len(self._ops)  # atomic len read; no lock on the poll path


class DDSFrontEnd:
    """The host file library.  One per storage application process."""

    def __init__(self, service: FileServiceRunner,
                 ring_capacity: int = 1 << 18,
                 max_progress: int | None = None,
                 doorbell=None):
        self.service = service
        self.ring_capacity = ring_capacity
        self.max_progress = max_progress
        # Work-signaled scheduling: every request ring this library creates
        # fires ``doorbell`` when a producer publishes messages, so inserts
        # from any thread mark the owning server runnable (no lost wakeups
        # even when the producer is not the server's own pump loop).
        self.doorbell = doorbell
        self._groups: dict[int, NotificationGroup] = {}
        self._file_group: dict[int, int] = {}
        self._next_group = 1
        self._lock = threading.Lock()
        # A default control group for applications that never create one.
        self._control_group = self.create_poll()

    # -- notification groups -------------------------------------------------------
    def create_poll(self) -> int:
        with self._lock:
            gid = self._next_group
            self._next_group += 1
        req = ProgressiveRing(self.ring_capacity, self.max_progress,
                              name=f"req-g{gid}")
        req.doorbell = self.doorbell
        resp = ResponseRing(self.ring_capacity, name=f"resp-g{gid}")
        group = NotificationGroup(gid, req, resp)
        # Rings are pre-registered to the DPU driver for DMA at creation time.
        self.service.register_group(gid, req, resp, interrupt=group.interrupt)
        with self._lock:
            self._groups[gid] = group
        return gid

    def poll_add(self, poll: int, file_handle: int) -> None:
        g = self._groups[poll]
        g.files.add(file_handle)
        self._file_group[file_handle] = poll

    def poll_wait(self, poll: int, timeout_s: float = 0.0) -> list[Completion]:
        return self._groups[poll].poll_wait(timeout_s)

    def any_outstanding(self) -> bool:
        """True while any notification group has un-polled operations."""
        for g in self._groups.values():
            if g.outstanding:
                return True
        return False

    def cancel(self, rid: int) -> bool:
        """Un-book a request whose completion will never arrive.

        The file service reports SHED requests (bounded E_NOSPC emergency
        path exhausted) through its ``shed_hook``; without cancellation the
        booked op would hold ``any_outstanding()`` true forever and wedge
        the owning server in a busy-but-unpumpable state."""
        for g in self._groups.values():
            if g.cancel(rid):
                return True
        return False

    # -- control plane ----------------------------------------------------------------
    def _sync_call(self, req: wire.Request) -> Completion:
        g = self._groups[self._control_group]
        req.request_id = g.next_request_id()
        g.book(_Op(req.request_id, req.op, req.file_id, req.offset, req.nbytes))
        g.req_ring.insert(frame(req.encode()))
        for _ in range(1_000_000):
            self.service.step()  # cooperative: drive the DPU when co-resident
            comps = g.poll_wait(0.0)
            if comps:
                return comps[0]
        raise TimeoutError("control op did not complete")

    def create_directory(self, name: str) -> int:
        c = self._sync_call(wire.Request(wire.OP_CREATE_DIR, 0, 0, 0, 0,
                                         name.encode()))
        if c.error != wire.E_OK:
            raise OSError(c.error, f"CreateDirectory({name})")
        return int.from_bytes(c.data[:4], "little")

    def create_file(self, name: str, directory: int = 0) -> int:
        c = self._sync_call(wire.Request(wire.OP_CREATE_FILE, 0, directory, 0, 0,
                                         name.encode()))
        if c.error != wire.E_OK:
            raise OSError(c.error, f"CreateFile({name})")
        return int.from_bytes(c.data[:4], "little")

    def delete_file(self, file_handle: int) -> None:
        c = self._sync_call(wire.Request(wire.OP_DELETE_FILE, 0, file_handle, 0, 0))
        if c.error != wire.E_OK:
            raise OSError(c.error, "DeleteFile")

    def fsync(self) -> None:
        c = self._sync_call(wire.Request(wire.OP_FSYNC, 0, 0, 0, 0))
        if c.error != wire.E_OK:
            raise OSError(c.error, "Fsync")

    # -- data plane (non-blocking) -------------------------------------------------
    def _group_for(self, file_handle: int) -> NotificationGroup:
        gid = self._file_group.get(file_handle, self._control_group)
        return self._groups[gid]

    def read_file(self, file_handle: int, offset: int, nbytes: int) -> int:
        """Non-blocking single read; returns the request id."""
        g = self._group_for(file_handle)
        rid = g.next_request_id()
        g.book(_Op(rid, wire.OP_READ, file_handle, offset, nbytes))
        g.req_ring.insert_v((
            _FRAMED_REQ.pack(_REQ_SIZE, wire.OP_READ, rid, file_handle,
                             offset, nbytes),))
        return rid

    def read_file_scatter(self, file_handle: int, offset: int,
                          bufs: Sequence[bytearray]) -> int:
        """Scattered read: one file I/O, results split across ``bufs``."""
        g = self._group_for(file_handle)
        rid = g.next_request_id()
        total = sum(len(b) for b in bufs)
        g.book(_Op(rid, wire.OP_READ, file_handle, offset, total, scatter=bufs))
        g.req_ring.insert_v((
            _FRAMED_REQ.pack(_REQ_SIZE, wire.OP_READ, rid, file_handle,
                             offset, total),))
        return rid

    def write_file(self, file_handle: int, offset: int, data) -> int:
        """Non-blocking single write; data inlined in the request (Fig 9).

        ``data`` may be ``bytes`` or a ``memoryview``: the gathered ring
        insert copies it exactly once — straight into the request ring (the
        DMA source).  No defensive copy, no header+payload join."""
        g = self._group_for(file_handle)
        rid = g.next_request_id()
        n = len(data)
        g.book(_Op(rid, wire.OP_WRITE, file_handle, offset, n))
        g.req_ring.insert_v((
            _FRAMED_REQ.pack(_REQ_SIZE + n, wire.OP_WRITE, rid, file_handle,
                             offset, n),
            data))
        return rid

    def submit_many(self, ops: Sequence[tuple]) -> list[int]:
        """Issue a burst of data-plane ops with ONE ring reservation per
        notification group.

        ``ops`` entries are ``("w", file_handle, offset, data)`` or
        ``("r", file_handle, offset, nbytes)``.  Request ids are reserved in
        bulk, bookkeeping is appended in bulk, and each group's messages go
        through :meth:`ProgressiveRing.insert_burst` — one tail CAS and one
        progress publish per burst chunk instead of per request.  Returns
        the request ids in op order.
        """
        per_group: dict[int, tuple[NotificationGroup, list, list, list]] = {}
        order: list[tuple[NotificationGroup, tuple]] = []
        for op in ops:
            gid = self._file_group.get(op[1], self._control_group)
            ent = per_group.get(gid)
            if ent is None:
                ent = per_group[gid] = (self._groups[gid], [], [], [0])
            ent[3][0] += 1
            order.append((ent[0], op))
        rid_of: dict[int, int] = {}
        for gid, (g, msgs, books, count) in per_group.items():
            rid_of[gid] = g.next_request_ids(count[0])
        rids: list[int] = []
        pack = _FRAMED_REQ.pack
        hdr_size = _REQ_SIZE
        for g, op in order:
            gid = g.group_id
            rid = rid_of[gid]
            rid_of[gid] = rid + 1
            rids.append(rid)
            kind, fh, offset, arg = op
            _, msgs, books, _n = per_group[gid]
            if kind == "w":
                n = len(arg)
                books.append(_Op(rid, wire.OP_WRITE, fh, offset, n))
                msgs.append((pack(hdr_size + n, wire.OP_WRITE, rid, fh,
                                  offset, n), arg))
            else:
                books.append(_Op(rid, wire.OP_READ, fh, offset, arg))
                msgs.append((pack(hdr_size, wire.OP_READ, rid, fh,
                                  offset, arg),))
        for g, msgs, books, _n in per_group.values():
            g.book_many(books)
            # Co-resident backpressure: when a burst chunk finds the ring
            # full, step the DPU service so the consumer drains (a blind
            # spin would deadlock a cooperative single-thread setup).
            g.req_ring.insert_burst(msgs, on_retry=self.service.step)
        return rids

    def write_file_gather(self, file_handle: int, offset: int,
                          bufs: Sequence[bytes]) -> int:
        """Gathered write: an array of source buffers, one file I/O.

        True scatter-gather — every buffer is copied once into the request
        ring; they are never joined into an intermediate buffer."""
        g = self._group_for(file_handle)
        rid = g.next_request_id()
        total = sum(len(b) for b in bufs)
        g.book(_Op(rid, wire.OP_WRITE, file_handle, offset, total))
        g.req_ring.insert_v((
            _FRAMED_REQ.pack(_REQ_SIZE + total, wire.OP_WRITE, rid,
                             file_handle, offset, total),
            *bufs))
        return rid

    # -- convenience synchronous wrappers (drive the co-resident service) ----------
    def _max_io(self, file_handle: int) -> int:
        """Largest single request: bounded by the ring's allowable progress
        (requests inline write data, Fig 9) and the response ring capacity."""
        g = self._group_for(file_handle)
        return min(g.req_ring.max_progress, g.resp_ring.capacity // 2) - 256

    def read_sync(self, file_handle: int, offset: int, nbytes: int) -> bytes:
        chunk = self._max_io(file_handle)
        parts = []
        for off in range(0, nbytes, chunk):
            n = min(chunk, nbytes - off)
            rid = self.read_file(file_handle, offset + off, n)
            parts.append(self._wait_one(file_handle, rid).data)
        return b"".join(parts)

    def write_sync(self, file_handle: int, offset: int, data: bytes) -> None:
        chunk = self._max_io(file_handle)
        for off in range(0, len(data), chunk):
            rid = self.write_file(file_handle, offset + off,
                                  data[off : off + chunk])
            c = self._wait_one(file_handle, rid)
            if c.error != wire.E_OK:
                raise OSError(c.error, "WriteFile")

    def _wait_one(self, file_handle: int, rid: int) -> Completion:
        g = self._group_for(file_handle)
        stash: list[Completion] = []
        for _ in range(1_000_000):
            self.service.step()
            for c in g.poll_wait(0.0):
                if c.request_id == rid:
                    if c.error != wire.E_OK and c.op == wire.OP_READ:
                        raise OSError(c.error, "ReadFile")
                    return c
                stash.append(c)
            self.service.fs.device.poll()
        raise TimeoutError(f"request {rid} did not complete")
