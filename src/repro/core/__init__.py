"""DDS core — the paper's primary contribution (DPU-optimized storage path).

Layers (paper section in parens):
  ring          progressive lock-free DMA ring buffers (§4.1)
  wire          request/response encodings on the rings (Fig 9)
  file_service  DPU segment file system + zero-copy ordered execution (§4.3)
  host_lib      host front-end file library (§4.2)
  cache_table   cuckoo-hash cache table (§6.1)
  traffic       bump-in-the-wire traffic director + PEP splitting (§5)
  offload       offload engine: OffPred/OffFunc/Cache/Invalidate (§6)
  dds_server    the assembled storage server + benchmark client (§8.1)
  simulate      calibrated event model for DPU-hardware figures (§8)
"""

from repro.core.cache_table import CacheTable
from repro.core.client import ClusterClient, ShardConnection
from repro.core.dds_server import DDSClient, DDSStorageServer, ServerConfig
from repro.core.file_service import FileServiceRunner, SegmentFS
from repro.core.host_lib import DDSFrontEnd
from repro.core.offload import OffloadAPI, OffloadEngine, ReadOp, WriteOp
from repro.core.ring import (DMAEngine, FaRMStyleRing, LockRing,
                             ProgressiveRing, ResponseRing)
from repro.core.traffic import (ApplicationSignature, FiveTuple,
                                TrafficDirector)

__all__ = [
    "CacheTable", "ClusterClient", "ShardConnection",
    "DDSClient", "DDSStorageServer", "ServerConfig",
    "FileServiceRunner", "SegmentFS", "DDSFrontEnd", "OffloadAPI",
    "OffloadEngine", "ReadOp", "WriteOp", "DMAEngine", "FaRMStyleRing",
    "LockRing", "ProgressiveRing", "ResponseRing", "ApplicationSignature",
    "FiveTuple", "TrafficDirector",
]
