"""Multi-tenant QoS policy: the collapsed server tuning surface + admission.

PRs 3-5 grew ``ServerConfig`` one knob at a time (write coalescing, response
delivery age, device priority interleave, host drain slice, read/write
fence).  This module collapses them — plus the tenancy controls introduced
with first-class ``tenant_id`` — into ONE validated, frozen dataclass with
named presets, so a deployment picks a *policy* instead of re-deriving six
interacting integers:

``QoSProfile``
    Every scheduling/batching knob the server honors, validated on
    construction (``from_dict`` additionally rejects unknown fields, so a
    typo'd config key is an error instead of a silently ignored default).

    Presets (``QoSProfile.preset(name)`` / ``ServerConfig(qos="latency")``):

      * ``latency``    — flush everything immediately: no write-run or
        response aging, small drain slices, a large normal-queue reserve so
        nothing sits behind a priority burst.
      * ``throughput`` — batch aggressively: long coalescing runs, deep
        device polls, wide drain slices.
      * ``isolation``  — the defaults plus tenancy enforcement ON: every
        tenant is weighted equally and admission-limited by a per-tenant
        token bucket, so an adversarial neighbor sheds instead of queueing.

``TokenBucket`` / ``TenantAdmission``
    Per-tenant admission control at the traffic director: each admitted
    request costs one token; buckets refill at ``rate`` tokens per tick of
    the deterministic scheduler clock up to ``burst``.  Over-limit requests
    are shed EARLY — at the demux, before they occupy a context-ring slot
    or a device queue entry — and the client sees a terminal ``E_SHED``
    carrying the bucket's retry-after hint.  Conservation holds exactly:
    ``granted + shed == offered`` (property-tested).

Weights (weighted-fair demux share) and rates (admission) are independent:
weights divide *service order* among backlogged tenants; rates bound how
much work a tenant may have admitted at all.  ``rate == 0`` means
unlimited (no bucket), the single-tenant default.
"""

from __future__ import annotations

from dataclasses import dataclass, field, fields, replace


@dataclass(frozen=True)
class QoSProfile:
    """Validated scheduling/batching/tenancy policy for one storage server.

    All knobs that tune the *data plane's* scheduling live here; structural
    sizing (device capacity, ring sizes, pool sizes) stays on
    :class:`~repro.core.dds_server.ServerConfig`.
    """

    # -- device scheduling (PR 5) -------------------------------------------
    device_queue_depth: int = 128      # per-poll completion budget
    prio_interleave: int = 4           # normal-queue reserve: budget // N
    # -- write coalescing + response delivery (PR 3/5) ----------------------
    coalesce_ticks: int = 2            # held write-run age bound
    coalesce_cap: int = 256            # max requests per coalesced run
    deliver_ticks: int = 2             # completed-response age bound
    host_drain_slice: int = 256        # host-wire packets per pump step
    read_write_fence: bool = False     # bounce reads of write-busy files
    # -- tenancy: weighted-fair service share -------------------------------
    default_weight: int = 1
    tenant_weights: dict = field(default_factory=dict)   # tenant -> weight
    # -- tenancy: token-bucket admission (0 == unlimited) -------------------
    default_rate: float = 0.0          # tokens (requests) per tick
    default_burst: float = 0.0         # bucket cap; 0 -> 8x rate
    tenant_rates: dict = field(default_factory=dict)     # tenant -> rate
    tenant_bursts: dict = field(default_factory=dict)    # tenant -> burst

    def __post_init__(self):
        for name in ("device_queue_depth", "prio_interleave", "coalesce_cap",
                     "host_drain_slice", "default_weight"):
            v = getattr(self, name)
            if not isinstance(v, int) or v < 1:
                raise ValueError(f"QoSProfile.{name} must be an int >= 1, "
                                 f"got {v!r}")
        for name in ("coalesce_ticks", "deliver_ticks"):
            v = getattr(self, name)
            if not isinstance(v, int) or v < 0:
                raise ValueError(f"QoSProfile.{name} must be an int >= 0, "
                                 f"got {v!r}")
        for name in ("default_rate", "default_burst"):
            v = getattr(self, name)
            if not isinstance(v, (int, float)) or v < 0:
                raise ValueError(f"QoSProfile.{name} must be >= 0, got {v!r}")
        # Normalize the per-tenant maps into plain (copied) dicts so a
        # caller mutating its argument cannot skew a live profile.
        for name, lo in (("tenant_weights", 1), ("tenant_rates", 0),
                         ("tenant_bursts", 0)):
            m = getattr(self, name)
            if not isinstance(m, dict):
                raise ValueError(f"QoSProfile.{name} must be a dict, "
                                 f"got {m!r}")
            clean = {}
            for t, v in m.items():
                if not isinstance(t, int) or t < 0:
                    raise ValueError(f"QoSProfile.{name}: tenant ids must "
                                     f"be ints >= 0, got {t!r}")
                if not isinstance(v, (int, float)) or v < lo:
                    raise ValueError(f"QoSProfile.{name}[{t}] must be "
                                     f">= {lo}, got {v!r}")
                clean[t] = v
            object.__setattr__(self, name, clean)

    # -- per-tenant effective values ----------------------------------------
    def weight_of(self, tenant: int) -> int:
        return int(self.tenant_weights.get(tenant, self.default_weight))

    def rate_of(self, tenant: int) -> float:
        return float(self.tenant_rates.get(tenant, self.default_rate))

    def burst_of(self, tenant: int) -> float:
        b = float(self.tenant_bursts.get(tenant, self.default_burst))
        if b <= 0:
            # A bucket with no explicit cap absorbs 8 ticks of its rate —
            # enough to ride out a pipelined batch without admitting an
            # unbounded backlog.
            b = max(self.rate_of(tenant) * 8.0, 1.0)
        return b

    def admission_enabled(self) -> bool:
        return self.default_rate > 0 or any(
            r > 0 for r in self.tenant_rates.values())

    def fairness_enabled(self) -> bool:
        """True when any tenant's service share differs from the default."""
        return bool(self.tenant_weights)

    # -- presets ------------------------------------------------------------
    @classmethod
    def preset(cls, name: str) -> "QoSProfile":
        try:
            build = _PRESETS[name]
        except KeyError:
            raise ValueError(
                f"unknown QoS preset {name!r}; "
                f"known: {sorted(_PRESETS)}") from None
        return build()

    @classmethod
    def latency(cls) -> "QoSProfile":
        return cls(coalesce_ticks=0, deliver_ticks=0, host_drain_slice=128,
                   prio_interleave=2)

    @classmethod
    def throughput(cls) -> "QoSProfile":
        return cls(coalesce_ticks=8, coalesce_cap=512, deliver_ticks=4,
                   host_drain_slice=1024, prio_interleave=8,
                   device_queue_depth=256)

    @classmethod
    def isolation(cls) -> "QoSProfile":
        return cls(default_rate=8.0, default_burst=64.0)

    @classmethod
    def from_dict(cls, d: dict) -> "QoSProfile":
        """Build a profile from a config mapping, rejecting unknown fields.

        An optional ``"profile"`` key names a preset to start from; every
        other key must be a :class:`QoSProfile` field and overrides it.
        """
        d = dict(d)
        base_name = d.pop("profile", None)
        known = {f.name for f in fields(cls)}
        unknown = sorted(set(d) - known)
        if unknown:
            raise ValueError(f"unknown QoSProfile field(s): {unknown}; "
                             f"known: {sorted(known)}")
        base = cls.preset(base_name) if base_name is not None else cls()
        return replace(base, **d) if d else base


_PRESETS = {
    "latency": QoSProfile.latency,
    "throughput": QoSProfile.throughput,
    "isolation": QoSProfile.isolation,
}


class TokenBucket:
    """One tenant's admission bucket against the deterministic tick clock.

    Lazy refill: tokens accrue ``rate`` per elapsed tick (capped at
    ``burst``) on the next ``grant`` — no per-tick bookkeeping, which
    matters because buckets are probed on the director's ingress hot path.
    """

    __slots__ = ("rate", "burst", "tokens", "last_tick")

    def __init__(self, rate: float, burst: float):
        self.rate = rate
        self.burst = burst
        self.tokens = burst          # a fresh tenant may burst immediately
        self.last_tick = 0

    def _refill(self, now: int) -> None:
        if now > self.last_tick:
            self.tokens = min(self.burst,
                              self.tokens + (now - self.last_tick) * self.rate)
            self.last_tick = now

    def grant(self, now: int, n: int) -> int:
        """Take up to ``n`` whole tokens; returns how many were granted."""
        self._refill(now)
        g = min(n, int(self.tokens))
        if g > 0:
            self.tokens -= g
        return g

    def retry_after(self, now: int) -> int:
        """Ticks until at least one token will be available (>= 1 when dry)."""
        self._refill(now)
        if self.tokens >= 1.0:
            return 0
        need = 1.0 - self.tokens
        return max(1, int(-(-need // self.rate)))  # ceil(need / rate)


class TenantAdmission:
    """Per-tenant token-bucket admission for one server's traffic director.

    Installed on the director as a pair of callbacks (``admit``/``on_shed``)
    so :mod:`repro.core.traffic` stays policy-free.  Conservation counters
    (``offered == granted + shed``) make over- and under-counting sheds a
    testable invariant rather than a log-diving exercise.
    """

    def __init__(self, profile: QoSProfile, clock):
        self.profile = profile
        self.clock = clock           # rebound by DDSStorageServer.adopt_clock
        self._buckets: dict[int, TokenBucket | None] = {}
        self.offered = 0
        self.granted = 0
        self.shed = 0
        self.tenant_shed: dict[int, int] = {}

    def _bucket(self, tenant: int) -> TokenBucket | None:
        try:
            return self._buckets[tenant]
        except KeyError:
            rate = self.profile.rate_of(tenant)
            b = (TokenBucket(rate, self.profile.burst_of(tenant))
                 if rate > 0 else None)    # None == unlimited
            self._buckets[tenant] = b
            return b

    def admit(self, tenant: int, n: int) -> int:
        """How many of ``n`` offered requests this tenant may enter NOW."""
        self.offered += n
        b = self._bucket(tenant)
        g = n if b is None else b.grant(self.clock.now, n)
        self.granted += g
        if g < n:
            dropped = n - g
            self.shed += dropped
            self.tenant_shed[tenant] = (
                self.tenant_shed.get(tenant, 0) + dropped)
        return g

    def retry_after(self, tenant: int) -> int:
        b = self._bucket(tenant)
        return 0 if b is None else b.retry_after(self.clock.now)

    def summary(self) -> dict:
        out = {"offered": self.offered, "granted": self.granted,
               "shed": self.shed}
        if self.tenant_shed:
            out["tenant_shed"] = dict(sorted(self.tenant_shed.items()))
        return out
