"""Deterministic network-fault injection for the DDS wires.

DDS assumes a lossless DPU network path; every transport the paper
targets can drop, duplicate, reorder, delay, or corrupt frames.  This
module makes those faults *first-class and reproducible*: a
:class:`FaultWire` wraps any :class:`~repro.core.traffic.Wire` or
:class:`~repro.core.traffic.FlowDemuxWire` and perturbs traffic
according to a seeded :class:`FaultSchedule`, in the shared tick domain
of the cluster clock — two same-seed runs inject the exact same faults
at the exact same points, so chaos runs gate like any other benchmark.

Fault taxonomy (one seeded draw per frame selects at most one fault):

  * **drop**    — the frame vanishes; any pool-backed payload is released
    (a NIC dropping a descriptor still completes it).
  * **duplicate** — the frame is delivered, then a payload-materialized
    copy is delivered right behind it (no shared pool ownership).
  * **reorder** — the frame is held and re-injected AFTER the next frame
    that passes (or after one tick if nothing follows), swapping adjacent
    frames the way a multi-path fabric does.
  * **delay**   — the frame is held for a seeded number of ticks and
    released when the clock reaches its due tick.
  * **corrupt** — one seeded bit of a payload copy is flipped; the frame's
    stamped checksum is left stale, so checksum-verifying receivers
    discard it as a loss (and non-verifying ones see the damage — the
    property tests cover both).

Timed partitions are orthogonal to the schedule:
``partition(a, b, until_tick)`` drops every frame whose flow connects
endpoints ``a`` and ``b`` (either direction) until the clock passes
``until_tick`` — the building block for partitioned-primary tests.

Liveness contract: a FaultWire counts its internally-held (delayed /
reorder-held) frames in ``__len__``/``__bool__``, so the scheduler's
busy-predicates keep the owning server runnable until every held frame
has been released — a delayed packet can never strand a quiet cluster.

With no schedule armed and no partitions, every operation delegates
straight to the wrapped wire — no RNG draw, no copy, byte-identical
traffic (property-tested).
"""

from __future__ import annotations

import random
from dataclasses import dataclass

from repro.core.traffic import FiveTuple, Packet

_KINDS = ("dropped", "duplicated", "reordered", "delayed", "corrupted",
          "partition_dropped")


@dataclass
class FaultSchedule:
    """Seeded per-direction fault rates, active in a tick window.

    Rates are per-frame probabilities; at most ONE fault fires per frame
    (a single uniform draw is compared against cumulative thresholds, so
    the draw sequence — and therefore the whole injection trace — is a
    pure function of ``seed`` and the traffic).
    """

    seed: int = 0
    drop: float = 0.0
    dup: float = 0.0
    reorder: float = 0.0
    delay: float = 0.0
    delay_ticks: tuple[int, int] = (1, 4)   # inclusive held-ticks range
    corrupt: float = 0.0
    start_tick: int = 0
    stop_tick: int | None = None            # None = never stops

    def armed(self) -> bool:
        return (self.drop or self.dup or self.reorder or self.delay
                or self.corrupt) > 0.0

    def active(self, now: int) -> bool:
        return (self.start_tick <= now
                and (self.stop_tick is None or now < self.stop_tick))


def _copy_packet(pkt: Packet) -> Packet:
    """Duplicate a packet WITHOUT sharing pool ownership: the copy's
    payload is materialized so releasing the original's slab can never
    pull bytes out from under the duplicate."""
    return Packet(pkt.flow, pkt.seq, bytes(pkt.payload), pkt.flags,
                  pkt.ack, None, pkt.epoch, pkt.csum)


class FaultWire:
    """Fault-injecting wrapper over a ``Wire`` or ``FlowDemuxWire``.

    Exposes the full surface of both wire types (``push``, ``push_many``,
    ``pop``, ``pop_many``, ``pop_flow``, ``drain_flow``, ``flows``,
    ``weight_of``, ``__len__``, ``__bool__``); faults are applied on the
    PUSH side, so consumers see a perturbed but otherwise ordinary wire.
    """

    def __init__(self, inner, clock, schedule: FaultSchedule | None = None,
                 flow_filter=None):
        self.inner = inner
        self.clock = clock
        self.schedule = schedule
        # Optional predicate(FiveTuple) -> bool: only flows it accepts are
        # eligible for injection; everything else passes through verbatim.
        # Lets a harness model a lossy CLIENT network over a reliable
        # backend fabric (e.g. exempt inter-shard replication flows, which
        # have no retransmit layer of their own).
        self.flow_filter = flow_filter
        self._rng = random.Random(schedule.seed if schedule else 0)
        # ``push_many`` has two shapes: Wire takes (pkts), FlowDemuxWire
        # takes (flow, pkts).  Duck-type once at wrap time.
        self._demux = hasattr(inner, "pop_flow")
        self._held: list[tuple[int, Packet]] = []     # (due_tick, pkt)
        self._reorder: list[tuple[int, Packet]] = []  # (held_at_tick, pkt)
        self._partitions: list[tuple[str, str, int]] = []
        self.totals = dict.fromkeys(_KINDS, 0)
        self.flow_counts: dict[FiveTuple, dict[str, int]] = {}

    # -- schedule / partition control ---------------------------------------------
    def partition(self, a: str, b: str, until_tick: int) -> None:
        """Drop every frame between endpoints ``a`` and ``b`` (matched
        against the flow's src/dst ids, either direction) until the
        shared clock passes ``until_tick``."""
        self._partitions.append((a, b, until_tick))

    def injection_stats(self) -> dict:
        """Totals plus per-flow injection counters (JSON-friendly keys)."""
        return {
            "totals": dict(self.totals),
            "held": len(self._held) + len(self._reorder),
            "flows": {
                f"{f.src_ip}:{f.src_port}->{f.dst_ip}:{f.dst_port}":
                    dict(c) for f, c in self.flow_counts.items()},
        }

    # -- internals ----------------------------------------------------------------
    def _count(self, flow: FiveTuple, kind: str) -> None:
        self.totals[kind] += 1
        fc = self.flow_counts.get(flow)
        if fc is None:
            fc = self.flow_counts[flow] = dict.fromkeys(_KINDS, 0)
        fc[kind] += 1

    def _partitioned(self, flow: FiveTuple, now: int) -> bool:
        if not self._partitions:
            return False
        live = [p for p in self._partitions if now < p[2]]
        if len(live) != len(self._partitions):
            self._partitions = live
        ends = (flow.src_ip, flow.dst_ip)
        for a, b, _until in live:
            if (a in ends) and (b in ends):
                return True
        return False

    def _deliver(self, pkt: Packet) -> None:
        self.inner.push(pkt)

    def _release_due(self) -> None:
        """Move every held frame whose due tick has arrived onto the
        inner wire (delayed frames by due tick; reorder-held frames once
        a tick has passed with nothing to slot them behind)."""
        now = self.clock.now
        if self._held:
            due = [h for h in self._held if h[0] <= now]
            if due:
                self._held = [h for h in self._held if h[0] > now]
                for _t, pkt in due:
                    self._deliver(pkt)
        if self._reorder:
            due = [h for h in self._reorder if h[0] < now]
            if due:
                self._reorder = [h for h in self._reorder if h[0] >= now]
                for _t, pkt in due:
                    self._deliver(pkt)

    def _inject(self, pkt: Packet) -> None:
        """Apply at most one fault to ``pkt`` and deliver what survives."""
        now = self.clock.now
        if self._partitioned(pkt.flow, now):
            self._count(pkt.flow, "partition_dropped")
            pkt.consumed()
            return
        sched = self.schedule
        if sched is None or not sched.active(now) or not sched.armed():
            self._deliver(pkt)
            self._flush_reorder()
            return
        if self.flow_filter is not None and not self.flow_filter(pkt.flow):
            self._deliver(pkt)
            self._flush_reorder()
            return
        r = self._rng.random()
        edge = sched.drop
        if r < edge:
            self._count(pkt.flow, "dropped")
            pkt.consumed()
            return
        edge += sched.dup
        if r < edge:
            self._count(pkt.flow, "duplicated")
            self._deliver(pkt)
            self._deliver(_copy_packet(pkt))
            self._flush_reorder()
            return
        edge += sched.reorder
        if r < edge:
            self._count(pkt.flow, "reordered")
            self._reorder.append((now, pkt))
            return
        edge += sched.delay
        if r < edge:
            lo, hi = sched.delay_ticks
            self._count(pkt.flow, "delayed")
            self._held.append((now + self._rng.randint(lo, hi), pkt))
            return
        edge += sched.corrupt
        if r < edge and pkt.nbytes:
            self._count(pkt.flow, "corrupted")
            buf = bytearray(pkt.payload)
            i = self._rng.randrange(len(buf))
            buf[i] ^= 1 << self._rng.randrange(8)
            pkt.consumed()   # the original's slab (if any) goes back
            self._deliver(Packet(pkt.flow, pkt.seq, bytes(buf), pkt.flags,
                                 pkt.ack, None, pkt.epoch, pkt.csum))
            self._flush_reorder()
            return
        self._deliver(pkt)
        self._flush_reorder()

    def _flush_reorder(self) -> None:
        """A frame just went through: reorder-held frames slot in behind
        it (the adjacent swap), in the order they were held."""
        if self._reorder:
            held, self._reorder = self._reorder, []
            for _t, pkt in held:
                self._deliver(pkt)

    def _passthrough(self) -> bool:
        """True when no fault machinery can possibly engage: delegate raw."""
        return (not self._partitions and not self._held and not self._reorder
                and (self.schedule is None
                     or not self.schedule.armed()
                     or not self.schedule.active(self.clock.now)))

    # -- push side ------------------------------------------------------------------
    def push(self, pkt: Packet) -> None:
        if self._passthrough():
            self.inner.push(pkt)
            return
        self._release_due()
        self._inject(pkt)

    def push_many(self, *args) -> None:
        if self._demux:
            flow, pkts = args
            if self._passthrough():
                self.inner.push_many(flow, pkts)
                return
            self._release_due()
            for pkt in pkts:
                self._inject(pkt)
        else:
            (pkts,) = args
            if self._passthrough():
                self.inner.push_many(pkts)
                return
            self._release_due()
            for pkt in pkts:
                self._inject(pkt)

    # -- pop side (held frames release on every consumer touch) ----------------------
    def pop(self):
        if not self._passthrough():
            self._release_due()
        return self.inner.pop()

    def pop_many(self, n: int):
        if not self._passthrough():
            self._release_due()
        return self.inner.pop_many(n)

    def pop_flow(self, flow):
        if not self._passthrough():
            self._release_due()
        return self.inner.pop_flow(flow)

    def drain_flow(self, flow):
        if not self._passthrough():
            self._release_due()
        return self.inner.drain_flow(flow)

    def flows(self):
        return self.inner.flows()

    # -- scheduler-facing surface ----------------------------------------------------
    @property
    def name(self) -> str:
        return self.inner.name

    @property
    def weight_of(self):
        return getattr(self.inner, "weight_of", None)

    @weight_of.setter
    def weight_of(self, fn):
        self.inner.weight_of = fn

    def __len__(self) -> int:
        return len(self.inner) + len(self._held) + len(self._reorder)

    def __bool__(self) -> bool:
        # Held frames keep the wire truthy: the busy-predicates must keep
        # the owning server runnable until every delayed frame lands.
        return bool(self.inner) or bool(self._held) or bool(self._reorder)


def wrap_director(director, clock,
                  ingress: FaultSchedule | None = None,
                  responses: FaultSchedule | None = None,
                  flow_filter=None) -> tuple[FaultWire, FaultWire]:
    """Install fault wrappers on a director's client-facing wires.

    ``ingress`` perturbs client->server frames (requests), ``responses``
    server->client frames (acks / read data).  ``flow_filter`` (optional
    predicate on the FiveTuple) restricts injection to the flows it
    accepts — e.g. exempt inter-shard replication flows, whose reliable
    fabric has no retransmit layer.  Returns the two wrappers (armed or
    not) so callers can add partitions and read injection counters.
    Wrap BEFORE creating clients only by convention — both sides resolve
    the wires through the director attribute on every access, so
    wrapping is transparent either way.
    """
    fin = FaultWire(director.ingress, clock, ingress, flow_filter)
    fout = FaultWire(director.to_client, clock, responses, flow_filter)
    director.ingress = fin
    director.to_client = fout
    return fin, fout


__all__ = ["FaultSchedule", "FaultWire", "wrap_director"]
