"""Request/response encodings on the DDS rings (paper Figure 9).

A *request* is a fixed header followed, for writes, by the inlined data so
the entire request moves host->DPU in a single DMA read.  A *response* is a
fixed header followed, for reads, by the read data.  Control-plane operations
(file/directory management) use the same header with op-specific payloads —
the paper optimizes the data plane; control ops are rare.

All integers little-endian.
"""

from __future__ import annotations

import struct
from dataclasses import dataclass

# ---- op codes ---------------------------------------------------------------
OP_READ = 1
OP_WRITE = 2
OP_CREATE_FILE = 3
OP_CREATE_DIR = 4
OP_DELETE_FILE = 5
OP_TRUNCATE = 6
OP_FSYNC = 7
OP_LIST_DIR = 8

DATA_PLANE_OPS = (OP_READ, OP_WRITE)

# ---- error codes --------------------------------------------------------------
E_PENDING = 0xFFFFFFFF  # response space pre-allocated, I/O not yet complete
E_OK = 0
E_NOENT = 2
E_IO = 5
E_INVAL = 22
E_NOSPC = 28
# Terminal client-side status for a request SHED under overload: the file
# service's bounded E_NOSPC emergency path gave up — or token-bucket
# admission refused it at the demux — so no response will ever arrive.
# Never travels on the wire — clients synthesize it from the lifecycle
# tracker's shed marks instead of spinning into a timeout.  The response
# BODY is a shed hint (see ``encode_shed_hint``) carrying the shedding
# tenant's bucket state, not empty bytes: the client learns WHEN a retry
# can be admitted instead of just that it was dropped.
E_SHED = 131
# Terminal client-side status for a request addressed to a shard the ring
# no longer routes there: the packet carried a stale ring epoch (or the
# owning shard died before responding) and a failover re-homed the keys.
# Like ``E_SHED`` it never travels on the wire — the director (or the
# cluster supervisor, for requests parked on a dead shard) marks the
# request terminally in the lifecycle tracker and the client synthesizes
# the status.  The body is a redirect hint (``encode_redirect_hint``)
# carrying the CURRENT ring epoch, so one retry against the repaired ring
# is guaranteed fresh.  Retryable: clients resubmit the same request id to
# the new owner (the old owner is dead or refused it, so the id cannot
# alias).
E_REDIRECT = 132

# Shed-hint body: tenant(u32) retry_after_ticks(u32).  ``retry_after`` is
# the shedding bucket's estimate of when one token will be available
# (admission sheds) or 1 (overload sheds: retry next tick is admissible).
SHED_HINT = struct.Struct("<II")

# Redirect-hint body: ring epoch(u32) after the repair that obsoleted the
# request's routing.  A client that re-routes with an epoch >= this value
# is acting on the post-failover ring.
REDIRECT_HINT = struct.Struct("<I")


def encode_redirect_hint(epoch: int) -> bytes:
    return REDIRECT_HINT.pack(min(max(epoch, 0), 0xFFFFFFFF))


def decode_redirect_hint(body: bytes | memoryview) -> int:
    """Decode an ``E_REDIRECT`` body -> current ring epoch (0 if absent)."""
    if len(body) < REDIRECT_HINT.size:
        return 0
    return REDIRECT_HINT.unpack_from(body, 0)[0]


def encode_shed_hint(tenant: int, retry_after: int) -> bytes:
    return SHED_HINT.pack(tenant & 0xFFFFFFFF,
                          min(max(retry_after, 0), 0xFFFFFFFF))


def decode_shed_hint(body: bytes | memoryview) -> tuple[int, int]:
    """Decode an ``E_SHED`` body -> ``(tenant, retry_after_ticks)``.

    Tolerates an empty body (legacy/unattributed sheds) as ``(0, 0)``.
    """
    if len(body) < SHED_HINT.size:
        return (0, 0)
    return SHED_HINT.unpack_from(body, 0)

# request header: op(u8) request_id(u64) file_id(u32) offset(u64) nbytes(u32)
REQ_HDR = struct.Struct("<BQIQI")
# response header: request_id(u64) error(u32) nbytes(u32)
RESP_HDR = struct.Struct("<QII")


@dataclass(slots=True)
class Request:
    op: int
    request_id: int
    file_id: int
    offset: int
    nbytes: int
    payload: bytes | memoryview = b""

    def encode(self) -> bytes:
        # join() accepts memoryview payloads without materializing them first
        return b"".join((REQ_HDR.pack(self.op, self.request_id, self.file_id,
                                      self.offset, self.nbytes), self.payload))


def decode_request(raw: bytes | memoryview) -> Request:
    """Decode a request; the payload stays a zero-copy view of ``raw``.

    The consumer's whole-batch DMA read owns the bytes; write payloads ride
    as ``memoryview`` slices all the way into ``submit_writev`` (§4.3
    "Eliminating data copies").  Callers needing ``str``/hashable payloads
    (control ops) materialize explicitly.
    """
    op, rid, fid, off, nbytes = REQ_HDR.unpack_from(raw, 0)
    payload = (raw if isinstance(raw, memoryview) else memoryview(raw))[REQ_HDR.size:]
    return Request(op, rid, fid, off, nbytes, payload)


@dataclass(slots=True)
class Response:
    request_id: int
    error: int
    nbytes: int
    payload: bytes = b""

    def encode(self) -> bytes:
        return RESP_HDR.pack(self.request_id, self.error, self.nbytes) + self.payload


def decode_response(raw: bytes | memoryview) -> Response:
    rid, err, nbytes = RESP_HDR.unpack_from(raw, 0)
    payload = bytes(raw[RESP_HDR.size : RESP_HDR.size + nbytes])
    return Response(rid, err, nbytes, payload)


def response_size_for(req: Request) -> int:
    """Expected response size — derivable in advance (§4.3 pre-allocation)."""
    if req.op == OP_READ:
        return RESP_HDR.size + req.nbytes
    if req.op in (OP_CREATE_FILE, OP_CREATE_DIR):
        return RESP_HDR.size + 4          # returns the new id
    if req.op == OP_LIST_DIR:
        return RESP_HDR.size + 4096       # bounded listing
    return RESP_HDR.size                   # write/others: header only
