"""Progressive lock-free DMA ring buffers (DDS §4.1, Figures 7-8).

Implements the paper's host<->DPU message rings:

  * ``ProgressiveRing``  — the DDS proposal.  A multi-producer single-consumer
    (request) / single-producer multi-consumer (response) byte ring with THREE
    pointers: ``head``, ``tail`` and the new ``progress`` pointer.  Producers
    atomically fetch-add the tail to reserve space, copy their message, then
    fetch-add progress to publish completion.  The consumer reads the whole
    ``[head, tail)`` range in ONE batch when ``progress == tail`` (Fig 8b) —
    the natural batching effect of §4.1.

  * ``LockRing``         — baseline (b) of Fig 17: the pointer update AND the
    message copy happen under a single lock.

  * ``FaRMStyleRing``    — baseline (a) of Fig 17: FaRM-style slot ring where
    each message carries a completion flag; the consumer polls each slot with
    a DMA read and releases it with a DMA write.  No batching.

Memory layout follows Fig 7 (right): a pointer area of cache-line-aligned
slots, physically ordered ``progress`` BEFORE ``tail`` so the consumer's
condition check (Fig 8b lines 1-2, highlighted) costs a SINGLE DMA read, and
a data area where messages are inserted.

Hardware adaptation (see DESIGN.md §2): host memory and DPU memory are two
NumPy regions; every cross-region access goes through :class:`DMAEngine`,
which counts operations and bytes and can model PCIe latency.  CPython has no
user-level CAS, so the two atomic fetch-adds are emulated with a micro
critical section *around the pointer arithmetic only* — the data path (the
``memcpy`` of the message, the batch read) never holds a lock, which is the
property the paper's design buys.
"""

from __future__ import annotations

import struct
import threading
import time
from collections import deque
from dataclasses import dataclass, field

import numpy as np

from repro.core import vector
from repro.core.lifecycle import TickHistogram

CACHE_LINE = 64

# Pointer-area offsets (Fig 7 right: progress precedes tail; head after).
OFF_PROG = 0 * CACHE_LINE
OFF_TAIL = 1 * CACHE_LINE
OFF_HEAD = 2 * CACHE_LINE
POINTER_AREA = 3 * CACHE_LINE


class Region:
    """A named flat memory region (host DRAM or DPU DDR)."""

    __slots__ = ("name", "buf", "_u64", "_mv")

    def __init__(self, name: str, size: int):
        self.name = name
        self.buf = np.zeros(size, dtype=np.uint8)
        # Cached u64 view of the buffer: pointer slots are cache-line
        # aligned, so u64 loads/stores index this view directly instead of
        # re-slicing + re-viewing on every access (the rings poll pointers
        # on every step).
        self._u64 = self.buf.view(np.uint64) if size % 8 == 0 else None
        # Cached byte view: memoryview slice-assignment copies straight
        # from any bytes-like source at C speed — no numpy ufunc dispatch
        # per (typically tiny) message copy.
        self._mv = memoryview(self.buf)

    def __len__(self) -> int:
        return len(self.buf)

    # Local (same-side) accessors -------------------------------------------------
    def load_u64(self, off: int) -> int:
        # fast path only for the aligned pointer slots; unaligned offsets
        # fall through rather than silently truncating off >> 3
        if self._u64 is not None and not off & 7:
            return int(self._u64[off >> 3])
        return int(self.buf[off : off + 8].view(np.uint64)[0])

    def store_u64(self, off: int, val: int) -> None:
        if self._u64 is not None and not off & 7:
            self._u64[off >> 3] = val
        else:
            self.buf[off : off + 8].view(np.uint64)[0] = np.uint64(val)

    def write(self, off: int, data) -> None:
        # Zero-copy staging: bytes, bytearray and (contiguous) memoryview
        # sources all copy straight into the backing buffer — no
        # intermediate bytes() materialization, no numpy dispatch.
        n = len(data)
        if n:
            self._mv[off : off + n] = data

    def read(self, off: int, n: int) -> bytes:
        return self.buf[off : off + n].tobytes()


@dataclass
class DMAStats:
    reads: int = 0
    writes: int = 0
    read_bytes: int = 0
    write_bytes: int = 0
    modeled_time_s: float = 0.0

    def snapshot(self) -> "DMAStats":
        return DMAStats(self.reads, self.writes, self.read_bytes,
                        self.write_bytes, self.modeled_time_s)

    def delta(self, before: "DMAStats") -> "DMAStats":
        return DMAStats(
            self.reads - before.reads,
            self.writes - before.writes,
            self.read_bytes - before.read_bytes,
            self.write_bytes - before.write_bytes,
            self.modeled_time_s - before.modeled_time_s,
        )


class DMAEngine:
    """DPU-issued DMA between host and DPU regions (BF-2 PCIe Gen4 model).

    Counts every transaction.  ``latency_s`` + ``bytes/bandwidth`` accumulate
    into modeled time (used by the calibrated benchmarks; never sleeps).
    """

    def __init__(self, latency_s: float = 1.5e-6, bandwidth_Bps: float = 24e9):
        self.latency_s = latency_s
        self.bandwidth_Bps = bandwidth_Bps
        self.stats = DMAStats()
        self._lock = threading.Lock()

    def _account(self, is_read: bool, nbytes: int) -> None:
        with self._lock:
            s = self.stats
            if is_read:
                s.reads += 1
                s.read_bytes += nbytes
            else:
                s.writes += 1
                s.write_bytes += nbytes
            s.modeled_time_s += self.latency_s + nbytes / self.bandwidth_Bps

    def read(self, src: Region, off: int, n: int) -> bytes:
        """DMA-read ``n`` bytes from a (host) region into the caller (DPU)."""
        self._account(True, n)
        return src.read(off, n)

    def write(self, dst: Region, off: int, data) -> None:
        """DMA-write bytes from the caller (DPU) into a (host) region."""
        self._account(False, len(data))
        dst.write(off, data)

    def read_u64_pair(self, src: Region, off: int) -> tuple[int, int]:
        """One DMA read covering two adjacent cache lines (P then T, Fig 7)."""
        raw = self.read(src, off, 2 * CACHE_LINE)
        a = struct.unpack_from("<Q", raw, 0)[0]
        b = struct.unpack_from("<Q", raw, CACHE_LINE)[0]
        return a, b

    def write_gather(self, dst: Region, items) -> None:
        """ONE accounted DMA transaction scattering ``(off, data)`` pairs.

        Models an SGL descriptor: the DPU posts a single DMA covering every
        element of a response burst, paying one PCIe transaction latency
        for the whole scatter list instead of one per message.
        """
        total = 0
        for _, d in items:
            total += len(d)
        self._account(False, total)
        for off, d in items:
            dst.write(off, d)

    def read_u64(self, src: Region, off: int) -> int:
        return struct.unpack("<Q", self.read(src, off, 8))[0]

    def write_u64(self, dst: Region, off: int, val: int) -> None:
        self.write(dst, off, struct.pack("<Q", val))


class _Atomics:
    """Micro critical sections emulating the CAS / fetch-add instructions.

    Only the pointer arithmetic runs under the lock (a handful of ns in HW);
    message copies happen outside.  See DESIGN.md §2 (CPython adaptation).
    ``ops`` counts atomic instructions for the contention model in
    benchmarks/fig17 (each would serialize for ~100 ns on real hardware).
    """

    def __init__(self, region: Region):
        self._region = region
        self._lock = threading.Lock()
        self.ops = 0

    def load(self, off: int) -> int:
        return self._region.load_u64(off)

    def store(self, off: int, val: int) -> None:
        with self._lock:
            self._region.store_u64(off, val)

    def fetch_add(self, off: int, inc: int) -> int:
        with self._lock:
            self.ops += 1
            old = self._region.load_u64(off)
            self._region.store_u64(off, old + inc)
            return old

    def compare_and_swap(self, off: int, expect: int, new: int) -> bool:
        with self._lock:
            self.ops += 1
            if self._region.load_u64(off) != expect:
                return False
            self._region.store_u64(off, new)
            return True


RETRY = "RETRY"
OK = "OK"


class ProgressiveRing:
    """The DDS progressive MPSC ring (Fig 7/8) over a host-memory region.

    ``capacity`` is the data-area size in bytes (power of two).  ``max_progress``
    is the paper's hyper-parameter M: the maximum in-flight (unconsumed) bytes,
    which bounds the batch the consumer picks up in one DMA read.
    """

    def __init__(self, capacity: int = 1 << 16, max_progress: int | None = None,
                 host_region: Region | None = None, base: int = 0,
                 name: str = "req-ring"):
        assert capacity & (capacity - 1) == 0, "capacity must be a power of 2"
        self.capacity = capacity
        self.max_progress = max_progress if max_progress is not None else capacity // 2
        assert self.max_progress <= capacity
        self.name = name
        total = POINTER_AREA + capacity
        self.host = host_region if host_region is not None else Region(f"host:{name}", total)
        self.base = base  # byte offset of this ring inside the host region
        self._atom = _Atomics(self.host)
        self._data0 = base + POINTER_AREA
        # Pointers start at 0 (monotonically increasing virtual offsets).
        # Work-signaled scheduling hook: fired AFTER a progress publish (the
        # moment inserted messages become consumable), so a producer thread
        # inserting into the ring marks the consuming server runnable — the
        # host->DPU mirror of the paper's doorbell DMA write.
        self.doorbell = None
        # Request-lifecycle instrumentation (repro.core.lifecycle): when a
        # TickClock is installed, every publish is stamped and the consumer
        # records publish->consume tick residency — the host-submit ->
        # DPU-fetch segment of the request lifecycle.  One deque entry per
        # PUBLISHED CHUNK (not per message), so the cost is amortized over
        # the batch exactly like the doorbell.
        self.clock = None
        self.residency = None            # TickHistogram, lazily created
        self._pub_ticks: deque = deque()  # (progress-after-publish, tick)

    # -- producer side (host threads), Fig 8a --------------------------------
    def _reserve(self, n: int) -> int | None:
        """CAS-reserve ``[tail, tail+n)``; returns the old tail or None."""
        tail = self._atom.load(self.base + OFF_TAIL)
        head = self._atom.load(self.base + OFF_HEAD)
        if tail - head + n > self.max_progress:
            return None  # insertions are outpacing consumption
        while True:
            if not self._atom.compare_and_swap(self.base + OFF_TAIL, tail, tail + n):
                tail = self._atom.load(self.base + OFF_TAIL)
                head = self._atom.load(self.base + OFF_HEAD)
                if tail - head + n > self.max_progress:
                    return None
                continue
            return tail

    def _publish(self, n: int) -> None:
        """Fetch-add the progress pointer (publish) + ring the doorbell.

        With a TickClock installed, the publish is also stamped so the
        consumer can record publish->consume residency ticks — one stamp
        per published chunk, amortized like the doorbell itself."""
        old = self._atom.fetch_add(self.base + OFF_PROG, n)
        clk = self.clock
        if clk is not None:
            self._pub_ticks.append((old + n, clk.now))
        db = self.doorbell
        if db is not None:
            db()

    def try_insert(self, msg: bytes) -> str:
        n = len(msg)
        assert 0 < n <= self.max_progress, "message exceeds max allowable progress"
        tail = self._reserve(n)
        if tail is None:
            return RETRY
        self._copy_in(tail, msg)                      # lock-free data path
        self._publish(n)                               # publish completion
        return OK

    def try_insert_v(self, parts) -> str:
        """Gathered insert: copy each part of ONE message straight into the
        ring data area (wrap-aware), with a single reservation and a single
        progress publish.  Producers build a message from (frame header,
        request header, payload view) without ever joining them into an
        intermediate buffer — the ring copy is the only copy the host pays
        (§4.2: write data is inlined into the request, Fig 9)."""
        n = 0
        for p in parts:
            n += len(p)
        assert 0 < n <= self.max_progress, "message exceeds max allowable progress"
        tail = self._reserve(n)
        if tail is None:
            return RETRY
        voff = tail
        for p in parts:
            self._copy_in(voff, p)
            voff += len(p)
        self._publish(n)                               # publish completion
        return OK

    def insert(self, msg: bytes, spin: int = 1_000_000) -> None:
        for _ in range(spin):
            if self.try_insert(msg) == OK:
                return
        raise TimeoutError(f"ring {self.name}: insert retry budget exhausted")

    def insert_v(self, parts, spin: int = 1_000_000) -> None:
        for _ in range(spin):
            if self.try_insert_v(parts) == OK:
                return
        raise TimeoutError(f"ring {self.name}: insert retry budget exhausted")

    def insert_burst(self, msgs: list, spin: int = 1_000_000,
                     on_retry=None) -> None:
        """Insert a burst of gathered messages with ONE reservation.

        ``msgs`` is a list of part-tuples (each a complete framed message).
        The tail CAS and the progress publish are paid once per contiguous
        chunk instead of once per message — the §4.1 batching effect applied
        to the producer side.  Bursts larger than ``max_progress`` fall back
        to chunking: each chunk is reserved and published atomically, so
        consumers always see whole messages and FIFO order is preserved.

        ``on_retry`` is invoked when a reservation fails (ring full) —
        co-resident callers pass the DPU service's ``step`` so the consumer
        actually drains between retries instead of a blind spin.
        """
        i = 0
        n_msgs = len(msgs)
        while i < n_msgs:
            total = 0
            j = i
            while j < n_msgs:
                sz = 0
                for p in msgs[j]:
                    sz += len(p)
                if total and total + sz > self.max_progress:
                    break
                total += sz
                j += 1
            assert total <= self.max_progress, \
                "single message exceeds max allowable progress"
            tail = None
            for _ in range(spin):
                tail = self._reserve(total)
                if tail is not None:
                    break
                if on_retry is not None:
                    on_retry()
            if tail is None:
                raise TimeoutError(
                    f"ring {self.name}: insert retry budget exhausted")
            voff = tail
            for k in range(i, j):
                for p in msgs[k]:
                    self._copy_in(voff, p)
                    voff += len(p)
            self._publish(total)  # one doorbell/stamp per chunk, like the CAS
            i = j

    def _copy_in(self, voff: int, msg: bytes) -> None:
        cap = self.capacity
        pos = voff % cap  # capacity is a power of two
        n = len(msg)
        first = min(n, cap - pos)
        self.host.write(self._data0 + pos, msg[:first])
        if first < n:  # wrap
            self.host.write(self._data0, msg[first:])

    # -- consumer side (DPU thread), Fig 8b ----------------------------------
    def consume(self, dma: DMAEngine) -> bytes | None:
        """One consumer step: returns a batch of raw bytes, or None (RETRY)."""
        # One DMA read covers progress AND tail (physical order P, T — Fig 7).
        prog, tail = dma.read_u64_pair(self.host, self.base + OFF_PROG)
        head = self._atom.load(self.base + OFF_HEAD)  # consumer-owned
        if prog != tail or tail == head:
            return None  # some producer mid-insert, or empty
        n = tail - head
        batch = self._dma_read_range(dma, head, n)
        # IncHead: publish consumption so producers see free space (DMA write).
        dma.write_u64(self.host, self.base + OFF_HEAD, tail)
        # keep the atomics view coherent for local producers
        self._atom.store(self.base + OFF_HEAD, tail)
        self._note_consumed(tail)
        return batch

    def consume_batch(self, dma: DMAEngine, max_rounds: int = 8) -> list[bytes]:
        """Burst consume: drain every available ``[head, tail)`` batch and
        publish ONE IncHead doorbell for the whole burst.

        Each round still pays the single progress/tail pair read (Fig 8b
        line 1 — that read is the poll), but the consumption publish — the
        DMA write producers wait on — is issued once per burst instead of
        once per batch, and the consumer-side head bookkeeping is local
        until then.  Returns the list of raw batches (possibly empty).
        """
        head = self._atom.load(self.base + OFF_HEAD)  # consumer-owned
        start = head
        batches: list[bytes] = []
        for _ in range(max_rounds):
            prog, tail = dma.read_u64_pair(self.host, self.base + OFF_PROG)
            if prog != tail or tail == head:
                break  # some producer mid-insert, or nothing new
            batches.append(self._dma_read_range(dma, head, tail - head))
            head = tail
        if head != start:
            # One doorbell covers every batch consumed this burst.
            dma.write_u64(self.host, self.base + OFF_HEAD, head)
            self._atom.store(self.base + OFF_HEAD, head)
            self._note_consumed(head)
        return batches

    def _note_consumed(self, head: int) -> None:
        """Record publish->consume residency for every chunk now consumed."""
        pt = self._pub_ticks
        if not pt:
            return
        clk = self.clock
        if clk is None:
            pt.clear()
            return
        res = self.residency
        if res is None:
            res = self.residency = TickHistogram()
        now = clk.now
        while pt and pt[0][0] <= head:
            res.add(now - pt.popleft()[1])

    def _dma_read_range(self, dma: DMAEngine, voff: int, n: int) -> bytes:
        cap = self.capacity
        pos = voff % cap
        first = min(n, cap - pos)
        out = dma.read(self.host, self._data0 + pos, first)
        if first < n:
            out += dma.read(self.host, self._data0, n - first)
        return out

    # -- introspection --------------------------------------------------------
    @property
    def head(self) -> int:
        return self._atom.load(self.base + OFF_HEAD)

    @property
    def tail(self) -> int:
        return self._atom.load(self.base + OFF_TAIL)

    @property
    def progress(self) -> int:
        return self._atom.load(self.base + OFF_PROG)


class ResponseRing:
    """SPMC mirror of :class:`ProgressiveRing` (DPU producer, host consumers).

    The DPU DMA-writes a batch of responses and then publishes the new tail
    with a second DMA write.  Host threads claim disjoint ranges by CAS on a
    claim pointer (HEAD) and publish completion on PROG so the producer can
    reclaim space — symmetric to the request ring.
    """

    def __init__(self, capacity: int = 1 << 16, host_region: Region | None = None,
                 base: int = 0, name: str = "resp-ring"):
        assert capacity & (capacity - 1) == 0
        self.capacity = capacity
        self.name = name
        total = POINTER_AREA + capacity
        self.host = host_region if host_region is not None else Region(f"host:{name}", total)
        self.base = base
        self._atom = _Atomics(self.host)
        self._data0 = base + POINTER_AREA

    # -- DPU producer ----------------------------------------------------------
    def free_space(self, dma: DMAEngine) -> int:
        prog = dma.read_u64(self.host, self.base + OFF_PROG)
        tail = self._atom.load(self.base + OFF_TAIL)
        return self.capacity - (tail - prog)

    def produce(self, dma: DMAEngine, batch: bytes) -> bool:
        n = len(batch)
        if n == 0:
            return True
        if self.free_space(dma) < n:
            return False
        tail = self._atom.load(self.base + OFF_TAIL)
        cap = self.capacity
        pos = tail % cap
        first = min(n, cap - pos)
        dma.write(self.host, self._data0 + pos, batch[:first])
        if first < n:
            dma.write(self.host, self._data0, batch[first:])
        dma.write_u64(self.host, self.base + OFF_TAIL, tail + n)
        self._atom.store(self.base + OFF_TAIL, tail + n)
        return True

    def publish_batch(self, dma: DMAEngine, parts, total: int | None = None) -> bool:
        """Deliver a burst of response fragments with ONE gathered DMA write
        and ONE tail doorbell.

        ``parts`` is a flat sequence of bytes-like fragments (frame headers
        interleaved with response-buffer memoryviews); nothing is joined or
        copied on the DPU side — each fragment lands straight in the host
        ring (the response DMA is the only copy).  All-or-nothing: returns
        False without side effects when the burst exceeds free space.
        """
        if total is None:
            total = 0
            for p in parts:
                total += len(p)
        if total == 0:
            return True
        if self.free_space(dma) < total:
            return False
        tail = self._atom.load(self.base + OFF_TAIL)
        cap = self.capacity
        data0 = self._data0
        items = []
        voff = tail
        for p in parts:
            n = len(p)
            pos = voff % cap
            first = min(n, cap - pos)
            if first == n:
                items.append((data0 + pos, p))
            else:  # fragment wraps the ring
                mv = p if isinstance(p, memoryview) else memoryview(p)
                items.append((data0 + pos, mv[:first]))
                items.append((data0, mv[first:]))
            voff += n
        dma.write_gather(self.host, items)   # one accounted DMA transaction
        dma.write_u64(self.host, self.base + OFF_TAIL, tail + total)  # doorbell
        self._atom.store(self.base + OFF_TAIL, tail + total)
        return True

    # -- host consumers ---------------------------------------------------------
    def try_claim(self, max_bytes: int | None = None) -> tuple[int, bytes] | None:
        """Claim and read the next unclaimed range; returns (claim_off, data)."""
        while True:
            head = self._atom.load(self.base + OFF_HEAD)
            tail = self._atom.load(self.base + OFF_TAIL)
            if head == tail:
                return None
            n = tail - head
            if max_bytes is not None:
                n = min(n, max_bytes)
            if self._atom.compare_and_swap(self.base + OFF_HEAD, head, head + n):
                data = self._local_read(head, n)
                self._atom.fetch_add(self.base + OFF_PROG, n)
                return head, data

    def _local_read(self, voff: int, n: int) -> bytes:
        cap = self.capacity
        pos = voff % cap
        first = min(n, cap - pos)
        out = self.host.read(self._data0 + pos, first)
        if first < n:
            out += self.host.read(self._data0, n - first)
        return out

    @property
    def tail(self) -> int:
        return self._atom.load(self.base + OFF_TAIL)


# ---------------------------------------------------------------------------
# Baselines for Fig 17.
# ---------------------------------------------------------------------------


class LockRing:
    """Baseline: a ring whose producers hold a lock across the whole insert.

    ``lock_held_s`` accumulates time inside the critical section — the
    serialization a real multi-core host pays (hidden by the GIL here).
    """

    def __init__(self, capacity: int = 1 << 16, name: str = "lock-ring"):
        assert capacity & (capacity - 1) == 0
        self.capacity = capacity
        self.name = name
        self.host = Region(f"host:{name}", POINTER_AREA + capacity)
        self._lock = threading.Lock()
        self._data0 = POINTER_AREA
        self.lock_held_s = 0.0

    def try_insert(self, msg: bytes) -> str:
        n = len(msg)
        with self._lock:  # pointer update AND memcpy under the lock
            t0 = time.perf_counter()
            tail = self.host.load_u64(OFF_TAIL)
            head = self.host.load_u64(OFF_HEAD)
            if tail - head + n > self.capacity:
                self.lock_held_s += time.perf_counter() - t0
                return RETRY
            cap = self.capacity
            pos = tail % cap
            first = min(n, cap - pos)
            self.host.write(self._data0 + pos, msg[:first])
            if first < n:
                self.host.write(self._data0, msg[first:])
            self.host.store_u64(OFF_TAIL, tail + n)
            self.lock_held_s += time.perf_counter() - t0
        return OK

    def consume(self, dma: DMAEngine) -> bytes | None:
        tail = dma.read_u64(self.host, OFF_TAIL)
        head = self.host.load_u64(OFF_HEAD)
        if tail == head:
            return None
        n = tail - head
        cap = self.capacity
        pos = head % cap
        first = min(n, cap - pos)
        out = dma.read(self.host, self._data0 + pos, first)
        if first < n:
            out += dma.read(self.host, self._data0, n - first)
        dma.write_u64(self.host, OFF_HEAD, tail)
        with self._lock:
            self.host.store_u64(OFF_HEAD, tail)
        return out


class FaRMStyleRing:
    """Baseline: FaRM-style slot ring [26].

    Fixed-size slots; the producer writes the message then sets a completion
    flag.  The consumer polls EACH slot's flag with a DMA read, DMA-reads the
    message, and DMA-writes to clear the flag ("release the space").  No
    batching, and polling via PCIe is expensive — the effects Fig 17 shows.
    """

    def __init__(self, slots: int = 1024, slot_size: int = 64,
                 name: str = "farm-ring"):
        self.slots = slots
        self.slot_size = slot_size  # includes 1 flag byte + 2 len bytes
        self.name = name
        self.host = Region(f"host:{name}", slots * slot_size)
        self._lock = threading.Lock()
        self._next = 0  # producer slot cursor
        self._cons = 0  # consumer slot cursor (DPU-local)

    def try_insert(self, msg: bytes) -> str:
        n = len(msg)
        assert n + 3 <= self.slot_size
        with self._lock:  # claim a slot
            slot = self._next
            off = (slot % self.slots) * self.slot_size
            if self.host.buf[off] != 0:  # slot not yet released by DPU
                return RETRY
            self._next += 1
        rec = struct.pack("<H", n) + bytes(msg)
        self.host.write(off + 1, rec)
        self.host.buf[off] = 1  # completion flag last
        return OK

    def consume_one(self, dma: DMAEngine) -> bytes | None:
        off = (self._cons % self.slots) * self.slot_size
        flag = dma.read(self.host, off, 1)  # poll via DMA
        if flag[0] == 0:
            return None
        raw = dma.read(self.host, off + 1, self.slot_size - 1)
        (n,) = struct.unpack_from("<H", raw, 0)
        msg = raw[2 : 2 + n]
        dma.write(self.host, off, b"\x00")  # release slot via DMA write
        self._cons += 1
        return msg


# ---------------------------------------------------------------------------
# Message framing shared by the storage path (Fig 9 encodings sit on top).
# ---------------------------------------------------------------------------

FRAME_HDR = struct.Struct("<I")  # total size of the framed message


def frame(msg: bytes) -> bytes:
    return FRAME_HDR.pack(len(msg)) + msg


def unframe_batch(batch) -> list[memoryview]:
    """Split a consumed batch back into individual framed messages.

    Zero-copy: returns ``memoryview`` slices over the batch buffer (a
    consumer's whole ``[head, tail)`` DMA read is split without duplicating
    any message bytes).  Views compare equal to ``bytes`` and unpack in
    place; callers that store or hash a message materialize it themselves.

    Large fixed-stride batches (the common shape: one op size repeated)
    are split columnar — :func:`repro.core.vector.uniform_stride` proves
    the stream uniform in one array compare, so no per-frame header
    unpack runs; irregular batches (and any remainder) take the scalar
    walk, which is also cheaper for short batches.
    """
    mv = batch if isinstance(batch, memoryview) else memoryview(batch)
    out = []
    off = 0
    n = len(mv)
    hdr = FRAME_HDR.size
    if n >= 512:
        u = vector.uniform_stride(mv, hdr, 0, min_frames=20)
        if u is not None:
            cnt, stride, _ = u
            out = [mv[i * stride + hdr:(i + 1) * stride] for i in range(cnt)]
            off = cnt * stride
    unpack = FRAME_HDR.unpack_from
    while off < n:
        (sz,) = unpack(mv, off)
        off += hdr
        out.append(mv[off : off + sz])
        off += sz
    return out
