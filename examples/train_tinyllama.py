"""End-to-end training driver: a ~100M-param TinyLlama-family model for a
few hundred steps, with DDS-backed write-behind checkpointing and the
ring-prefetched data pipeline.

This is the (b) end-to-end example: config -> pipeline -> train loop ->
checkpoints, all through the public API.  On CPU it uses a scaled-down
width so a few hundred steps finish in minutes; pass --full-width on real
hardware.

Run:  PYTHONPATH=src python examples/train_tinyllama.py --steps 200
"""

import argparse
import dataclasses
import sys
import time

sys.path.insert(0, "src")

from repro.configs import get_config
from repro.core.dds_server import DDSStorageServer, ServerConfig
from repro.data.pipeline import BatchSpec, TokenPipeline
from repro.models.registry import build_model
from repro.storage.checkpoint import CheckpointManager
from repro.train.loop import TrainConfig, Trainer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--full-width", action="store_true",
                    help="use the real tinyllama-1.1b config (needs a TPU)")
    args = ap.parse_args()

    cfg = get_config("tinyllama_1p1b")
    if not args.full_width:
        # ~100M-param family member runnable on CPU for a demo.
        cfg = dataclasses.replace(cfg, num_layers=4, d_model=512,
                                  num_heads=8, num_kv_heads=2, head_dim=64,
                                  d_ff=1408, vocab_size=8192)
    api = build_model(cfg)
    n_params = cfg.param_count()
    print(f"arch={cfg.name} params~{n_params / 1e6:.0f}M "
          f"layers={cfg.num_layers} d_model={cfg.d_model}")

    # structured stream: learnable next-token process (uniform-random data
    # would have an irreducible loss floor of ln(vocab) ~= 9.0)
    pipeline = TokenPipeline(BatchSpec(args.batch, args.seq, cfg.vocab_size),
                             seed=0, structured=True)
    ckpt = CheckpointManager(DDSStorageServer(ServerConfig(
        device_capacity=1 << 30)), keep=2)
    tcfg = TrainConfig(peak_lr=args.lr, warmup_steps=max(10, args.steps // 10),
                       total_steps=args.steps)
    trainer = Trainer(api, tcfg, pipeline, checkpoint_mgr=ckpt,
                      ckpt_every=args.ckpt_every)

    if trainer.restore_latest():
        print(f"resumed from checkpoint at step {trainer.step}")

    t0 = time.time()
    hist = trainer.run(args.steps)
    dt = time.time() - t0
    tput = args.steps * args.batch * args.seq / dt
    print(f"\nstep   loss     grad_norm")
    for h in hist[:: max(1, len(hist) // 10)]:
        print(f"{h['step']:5d}  {h['loss']:.4f}  {h['grad_norm']:.3f}")
    print(f"\n{args.steps} steps in {dt:.1f}s = {tput:,.0f} tokens/s (CPU)")
    print(f"loss {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f}")
    print(f"checkpoints kept: {sorted(ckpt._manifests())}")


if __name__ == "__main__":
    main()
