"""Quickstart: a DDS storage server end to end in ~60 lines.

Shows the paper's whole pipeline: a host application adopts the DDS
front-end file library, a remote client's READS are served entirely by the
DPU (traffic director -> offload engine -> SSD, zero host CPU), and WRITES
take the PEP-split host path.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import sys

sys.path.insert(0, "src")

from repro.core import DDSClient, DDSStorageServer, ServerConfig


def main() -> None:
    # 1. Stand up a storage server (host + DPU + RAM-backed NVMe model).
    server = DDSStorageServer(ServerConfig())

    # 2. The host application uses the DDS front-end library instead of the
    #    OS file system — same API shape: CreateFile / WriteFile / ReadFile.
    fid = server.frontend.create_file("table.pages")
    server.frontend.write_sync(fid, 0, b"\xAB" * 65536)
    server.run_until_idle()

    # 3. A remote compute server issues reads: they match the application
    #    signature, pass the offload predicate, and never touch the host.
    client = DDSClient(server)
    status, page = client.wait(client.read(fid, 4096, 8192))
    assert status == 0 and page == b"\xAB" * 8192

    print(f"offloaded reads : {server.offload.stats.completed}")
    print(f"host CPU burned : {server.host_cpu_busy_s * 1e6:.0f} us "
          f"(reads bypass the host entirely)")

    # 4. Writes are host work (log replay / RMW need big cores + memory).
    status, _ = client.wait(client.write(fid, 0, b"fresh-data!"))
    assert status == 0
    status, back = client.wait(client.read(fid, 0, 11))
    assert back == b"fresh-data!"

    print(f"host-path writes: {server.director.stats.to_host}")
    print(f"DPU DMA traffic : {server.dma.stats.reads} reads / "
          f"{server.dma.stats.writes} writes "
          f"({server.dma.stats.read_bytes + server.dma.stats.write_bytes} B)")
    print("OK: reads offloaded to the DPU; writes executed on the host.")


if __name__ == "__main__":
    main()
