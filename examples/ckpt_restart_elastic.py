"""Fault-tolerance example: crash mid-training, restore, shrink the world.

Simulates a host failure at step 23 of a 40-step run with checkpoints every
10 steps: the supervisor restores step 20 from the DDS store, drops the
dead host (elastic shrink), and finishes — then an elastic RESTORE reshards
the final checkpoint onto a different data-parallel world size.

Run:  PYTHONPATH=src python examples/ckpt_restart_elastic.py
"""

import dataclasses
import sys

import numpy as np

sys.path.insert(0, "src")

from repro.configs import get_config, reduced_config
from repro.core.dds_server import DDSStorageServer, ServerConfig
from repro.data.pipeline import BatchSpec, TokenPipeline
from repro.distributed.fault_tolerance import TrainSupervisor
from repro.models.registry import build_model
from repro.storage.checkpoint import CheckpointManager
from repro.train.loop import TrainConfig, Trainer


def main() -> None:
    cfg = dataclasses.replace(reduced_config(get_config("tinyllama_1p1b")),
                              num_layers=2, d_model=64, num_heads=2,
                              num_kv_heads=2, head_dim=32, d_ff=128,
                              vocab_size=256)
    api = build_model(cfg)
    pipeline = TokenPipeline(BatchSpec(4, 32, cfg.vocab_size), seed=0)
    ckpt = CheckpointManager(DDSStorageServer(ServerConfig()), keep=3)
    trainer = Trainer(api, TrainConfig(peak_lr=1e-3, warmup_steps=4,
                                       total_steps=64),
                      pipeline, checkpoint_mgr=ckpt, ckpt_every=10)

    failures = {23: "host2"}
    sup = TrainSupervisor(trainer, [f"host{i}" for i in range(4)],
                          inject_failure=lambda s: failures.pop(s, None))
    sup.run(40)
    ev = sup.events[0]
    print(f"crash of {ev.host} at step {ev.step}: action={ev.action}")
    print(f"restored from checkpoint, surviving hosts={sup.hosts}")
    print(f"finished at step {trainer.step}, restarts={sup.restarts}")

    # Elastic restore: re-shard the final checkpoint onto a 2-way world.
    latest = ckpt.latest_step()
    template = {"params": trainer.params, "mu": trainer.opt.mu,
                "nu": trainer.opt.nu}
    shard0 = ckpt.restore_elastic(latest, template, 0, 2)
    shard1 = ckpt.restore_elastic(latest, template, 1, 2)
    full = ckpt.restore(latest, template)
    leaf = "embedding/embed"
    w0 = shard0["params"]["embedding"]["embed"]
    w1 = shard1["params"]["embedding"]["embed"]
    wf = np.asarray(full["params"]["embedding"]["embed"])
    ok = np.allclose(np.concatenate([w0, w1]), wf)
    print(f"elastic restore onto 2-way FSDP world: shards stitch exactly "
          f"-> {ok}")


if __name__ == "__main__":
    main()
