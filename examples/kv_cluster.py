"""Sharded KV cluster walkthrough: §9.2 on N storage servers.

Stands up a 4-shard DDS cluster, runs the FASTER-style KV workload through
the batched/pipelined cluster client, and shows the paper's division of
labor at cluster scale:

  * PUTs execute on each shard's HOST (appends to that shard's record log);
    cache-on-write arms the DPU with {key -> (file, offset, size)};
  * GETs are served entirely by the DPUs — zero host CPU;
  * DELETE pulls the record back through the host read path, firing
    invalidate-on-read so the DPU can never serve a dead record.

Run:  PYTHONPATH=src python examples/kv_cluster.py
"""

import sys

sys.path.insert(0, "src")

from repro.apps.kv_store import KVClient, ShardedKVStore


def main() -> None:
    # 1. Four storage servers (each a full Fig-6 box: host + DPU + device)
    #    behind consistent-hash key sharding.
    store = ShardedKVStore(num_shards=4)
    client = KVClient(store)

    # 2. Load 64 user profiles.  All PUTs for a shard travel in ONE batched
    #    network message; shards run their host paths in parallel.
    keys = [f"user:{i:03d}".encode() for i in range(64)]
    put_rids = [client.put(k, b"profile-of-" + k) for k in keys]
    client.flush()
    client.run_until_idle()
    loc = client.wait_put(put_rids[0])
    print(f"PUT acks carry the on-disk location, e.g. {keys[0].decode()} -> "
          f"(file={loc.file_id}, off={loc.offset}, size={loc.size})")

    # 3. Read them all back — every GET is answered by a DPU, not a host.
    get_rids = {k: client.get(k) for k in keys}
    for k in keys:
        assert client.wait_value(get_rids[k]) == b"profile-of-" + k
    print(f"GETs served by DPUs : {store.dpu_served_gets()}/{len(keys)}")
    print(f"GETs served by hosts: {store.host_served_gets()}")

    # 4. Per-shard view: consistent hashing spread the keys out.
    for i, s in enumerate(store.shard_stats()):
        print(f"  shard {i}: puts={s['puts']:2d} dpu_gets={s['dpu_gets']:2d} "
              f"log={s['log_bytes']}B")

    # 5. Overwrite + delete: the cache table follows the host's truth.
    client.wait_put(client.put(keys[0], b"v2"))
    assert client.wait_value(client.get(keys[0])) == b"v2"
    client.net.wait(client.delete(keys[0]))
    assert client.wait_value(client.get(keys[0])) is None
    print("overwrite + delete kept the DPU cache coherent "
          "(Cache on write, Invalidate on read-for-update)")


if __name__ == "__main__":
    main()
