"""Serving example: continuous-batched decode + DDS-backed KV-block paging.

Two parts:
  1. ``BatchScheduler`` serves a small LM with slot-based continuous
     batching (requests join/leave between decode steps).
  2. ``PagedKVEngine`` demonstrates the DDS integration for long contexts:
     KV blocks spill from the HBM pool to the page store (HOST path) and
     cold blocks are fetched back through the DPU OFFLOAD path.

Run:  PYTHONPATH=src python examples/serve_paged_kv.py
"""

import dataclasses
import sys

import numpy as np

sys.path.insert(0, "src")

import jax

from repro.configs import get_config, reduced_config
from repro.models.registry import build_model
from repro.serve.engine import BatchScheduler, PagedKVEngine, Request
from repro.storage.pagestore import PageStore


def continuous_batching() -> None:
    cfg = dataclasses.replace(reduced_config(get_config("tinyllama_1p1b")),
                              num_layers=2, vocab_size=512)
    api = build_model(cfg)
    params, _ = api.init(jax.random.PRNGKey(0))
    sched = BatchScheduler(api, params, slots=4, cache_len=64)
    rng = np.random.default_rng(0)
    for rid in range(8):  # 8 requests over 4 slots
        sched.submit(Request(rid, rng.integers(0, 512, size=4), max_new=6))
    steps = done = 0
    while done < 8 and steps < 200:
        done += sched.step()
        steps += 1
    print(f"continuous batching: 8 requests over 4 slots, "
          f"{steps} decode steps, all done={done == 8}")


def kv_paging() -> None:
    store = PageStore(page_size=4096, num_pages=512)
    engine = PagedKVEngine(store, block_bytes=2048, hbm_blocks=8)
    blob = bytes(range(256)) * 8  # one KV block's bytes
    # A long sequence produces 32 KV blocks; only 8 fit in HBM.
    for blk in range(32):
        engine.put_block(seq=0, layer=0, blk=blk, data=blob)
    print(f"kv paging: spilled {engine.spills} cold blocks to the store "
          f"(host path)")
    # Attention over an old context region: cold blocks come back through
    # the DPU offload path.
    for blk in range(4):
        data = engine.get_block(0, 0, blk)
        assert data is not None and data[:16] == blob[:16]
    print(f"kv paging: fetched {engine.fetches} cold blocks via DPU offload "
          f"(offloaded reads so far: {store.server.offload.stats.completed})")
    hot = engine.get_block(0, 0, 31)   # still HBM-resident
    print(f"kv paging: hot block hit in HBM (hits={engine.hits})")


def main() -> None:
    continuous_batching()
    kv_paging()


if __name__ == "__main__":
    main()
