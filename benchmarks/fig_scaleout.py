"""Cluster-scale scheduler ops/sec: 16 shards under Zipfian-skewed KV load.

PR 2/3 made one server's read and write paths O(1) per request; this
benchmark holds the CLUSTER layer to the same standard.  The pre-overhaul
run loop polled every shard on every iteration (``DDSCluster.pump`` stepped
all N servers; ``run_until_idle`` swept them three more times to detect
quiescence), so wall-clock cost per op grew with shard count even when most
shards were idle — the opposite of scale-out economics.  The work-signaled
ready-set scheduler makes a scheduling round cost track *active* work.

Two measurements, both on the §9.2 sharded KV store:

  * **zipf** — a 16-shard cluster under a Zipfian-skewed mixed workload:
    two clients run closed-loop READ-MODIFY-WRITE rounds against a fixed
    hot key set with Zipf(a)-distributed ranks (a handful of shards own
    nearly all the heat): a burst of GETs settles (``run_until_idle``),
    then overwrite-PUTs conditioned on those reads settle, plus a slow
    fresh-PUT/DEL churn stream.  Each round has several settle points —
    the bursty, dependency-chained pattern where dispatch-loop overhead
    dominates and which no other benchmark covers (``fig_hotpath``/
    ``fig_writepath`` drive saturated open-loop pipelines).  GETs touch
    only warmed keys, so every GET is DPU-served and the modeled us/req
    is fully deterministic.
  * **idle-cost** — the same round shape with ALL keys placed on one shard,
    run against a 16-shard and a 1-shard cluster: the calibrated ops/sec
    ratio is the price of fifteen idle shards (the pre-overhaul loop paid
    ~16x pump overhead here; the ready set must keep it near parity).

Results go to ``BENCH_scaleout.json``.  Calibration, JSON layout
(``baseline``/``current``/``last_run``) and the gates mirror
``fig_writepath``:

  * full mode asserts >= ``FULL_SPEEDUP_GATE`` (2.0x) calibrated ops/sec
    over the recorded pre-overhaul baseline, with modeled us/req within
    ``MODELED_DRIFT`` (5%) of it — the simulator got faster, the physics
    did not move;
  * full mode also asserts the idle-cost criterion: single-shard traffic
    on the 16-shard cluster reaches >= ``IDLE_PARITY_GATE`` (70%) of the
    1-shard cluster's rate;
  * ``--smoke`` (CI fast lane) runs a reduced config and fails on a >30%
    calibrated regression vs the recorded ``current`` numbers.

The driver is version-agnostic (burst client APIs are used when present,
per-op calls otherwise), so ``--record-baseline`` runs unmodified against
the pre-overhaul tree.
"""

from __future__ import annotations

import gc
import json
import os
import struct
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.common import emit, section  # noqa: E402
from repro.apps.kv_store import KVClient, ShardedKVStore  # noqa: E402
from repro.core.dds_server import ServerConfig  # noqa: E402
from repro.distributed.cluster import HashRing  # noqa: E402

JSON_PATH = os.path.join(os.path.dirname(__file__), "..",
                         "BENCH_scaleout.json")

FULL_SPEEDUP_GATE = 2.0       # acceptance: scheduler >= 2x the pre-PR loop
SMOKE_REGRESSION_GATE = 0.70  # CI: fail below 70% of recorded current
MODELED_DRIFT = 0.05          # modeled us/req must stay within 5%
IDLE_PARITY_GATE = 0.70       # 1-of-16-shard traffic >= 70% of 1-shard rate

CONFIGS = {
    "full": dict(shards=16, clients=2, hot_keys=48, zipf_a=3.0, rounds=120,
                 gets=2, overwrites=1, churn_every=4, value_size=64,
                 idle_rounds=120, idle_gets=8, idle_overwrites=2),
    "smoke": dict(shards=16, clients=2, hot_keys=48, zipf_a=3.0, rounds=48,
                  gets=2, overwrites=1, churn_every=4, value_size=64,
                  idle_rounds=0, idle_gets=8, idle_overwrites=2),
}

ZIPF_SEED = 0xD15C0


def calibrate(iters: int = 200_000) -> float:
    """Reference ops/sec of a fixed pure-Python loop (machine-speed proxy).

    Same spirit as ``fig_hotpath``/``fig_writepath``: struct packing, dict
    traffic and bytes slicing — the primitives the scheduler loop leans on.
    """
    pack = struct.Struct("<QII").pack
    blob = bytes(range(256)) * 8
    t0 = time.perf_counter()
    d: dict[int, bytes] = {}
    for i in range(iters):
        d[i & 1023] = blob[i & 255 : (i & 255) + 64]
        pack(i, i & 0xFFFF, 64)
    dt = time.perf_counter() - t0
    return iters / dt


def _issue_gets(cli: KVClient, keys: list) -> None:
    if hasattr(cli, "get_many"):       # post-overhaul burst API
        cli.get_many(keys)
    else:                              # pre-PR client: per-op calls
        for k in keys:
            cli.get(k)


def _issue_puts(cli: KVClient, items: list) -> None:
    if hasattr(cli, "put_many"):
        cli.put_many(items)
    else:
        for k, v in items:
            cli.put(k, v)


def _settle(clients: list) -> None:
    """End-of-round convergence: let every client's run loop go idle."""
    for cli in clients:
        cli.net.run_until_idle()


def _zipf_ranks(cfg: dict, total: int) -> list[int]:
    """The skewed rank sequence, precomputed (untimed) and seeded: the
    exact same key sequence every rep, every run, every machine."""
    rng = np.random.default_rng(ZIPF_SEED)
    return [(int(z) - 1) % cfg["hot_keys"]
            for z in rng.zipf(cfg["zipf_a"], size=total)]


def _warm(store: ShardedKVStore, clients: list, keys: list, value: bytes,
          fresh: list) -> None:
    """Untimed: PUT-ack every hot key (arms the DPU cache) + churn pool."""
    for k in keys:
        clients[0].put(k, value)
    for k in fresh:
        clients[0].put(k, value)
    clients[0].flush()
    _settle(clients)


def run_zipf_workload(cfg: dict) -> dict:
    """Drive the settle-per-round Zipfian workload; return measured rates."""
    store = ShardedKVStore(num_shards=cfg["shards"],
                           config=ServerConfig(device_capacity=1 << 26,
                                               cache_items=1 << 14))
    cluster = store.cluster
    clients = [KVClient(store) for _ in range(cfg["clients"])]
    value = bytes(range(256))[: cfg["value_size"]]
    hot = [b"hot-%04d" % i for i in range(cfg["hot_keys"])]
    fresh = [b"fresh-w%d" % i for i in range(8)]
    _warm(store, clients, hot, value, fresh)

    per_round = cfg["gets"] + cfg["overwrites"]
    ranks = _zipf_ranks(cfg, cfg["rounds"] * cfg["clients"] * per_round)
    rk = iter(ranks)
    total = 0
    gets_total = 0
    dpu_before = store.dpu_served_gets()
    modeled_before = cluster.makespan_s()
    gc.collect()
    gc.disable()   # keep collector pauses out of the timed region
    t0 = time.perf_counter()
    for r in range(cfg["rounds"]):
        # Read phase: every client GETs its Zipf-ranked keys and BLOCKS on
        # the values (closed loop — the writes below depend on them).
        for cli in clients:
            _issue_gets(cli, [hot[next(rk)] for _ in range(cfg["gets"])])
            total += cfg["gets"]
            gets_total += cfg["gets"]
            cli.flush()
        _settle(clients)
        # Modify phase: read-modify-write — overwrite-PUT the hot keys the
        # reads conditioned on, and settle before the next round's reads.
        for cli in clients:
            _issue_puts(cli, [(hot[next(rk)], value)
                              for _ in range(cfg["overwrites"])])
            total += cfg["overwrites"]
            cli.flush()
        if r % cfg["churn_every"] == 0:
            # slow churn stream: one fresh append + one DEL of a key that
            # settled at least a full round ago (host read-for-update,
            # fires invalidate-on-read) — always through client 0
            k = b"fresh-r%d" % r
            clients[0].put(k, value)
            fresh.append(k)
            clients[0].delete(fresh.pop(0))
            total += 2
            clients[0].flush()
        _settle(clients)
    elapsed = time.perf_counter() - t0
    gc.enable()

    dpu_gets = store.dpu_served_gets() - dpu_before
    assert dpu_gets == gets_total, \
        f"GET offload not deterministic: {dpu_gets}/{gets_total} DPU-served"
    modeled_s = cluster.makespan_s() - modeled_before
    return {
        "requests": total,
        "wall_s": elapsed,
        "ops_per_s": total / elapsed,
        "modeled_us_per_req": modeled_s / total * 1e6,
        "dpu_get_frac": dpu_gets / max(gets_total, 1),
    }


def _single_shard_keys(n: int, ring_shards: int) -> list:
    """Keys that the ``ring_shards``-way ring places on shard 0."""
    ring = HashRing(ring_shards)
    keys, i = [], 0
    while len(keys) < n:
        k = b"idle-%d" % i
        if ring.shard_for(k) == 0:
            keys.append(k)
        i += 1
    return keys


def run_idle_workload(cfg: dict, num_shards: int) -> float:
    """Ops/sec with every key on ONE shard of a ``num_shards`` cluster."""
    store = ShardedKVStore(num_shards=num_shards,
                           config=ServerConfig(device_capacity=1 << 26,
                                               cache_items=1 << 14))
    cli = KVClient(store)
    value = bytes(range(256))[: cfg["value_size"]]
    # Placement is ring-stable: keys chosen for shard 0 of the 16-ring all
    # live on the only shard of a 1-shard ring too, so both clusters run
    # the IDENTICAL workload.
    keys = _single_shard_keys(cfg["hot_keys"], cfg["shards"])
    fresh: list = []
    _warm(store, [cli], keys, value, fresh)

    per_round = cfg["idle_gets"] + cfg["idle_overwrites"]
    total = 0
    gc.collect()
    gc.disable()
    t0 = time.perf_counter()
    for r in range(cfg["idle_rounds"]):
        _issue_gets(cli, [keys[(r + i) % len(keys)]
                          for i in range(cfg["idle_gets"])])
        _issue_puts(cli, [(keys[(r + i) % len(keys)], value)
                          for i in range(cfg["idle_overwrites"])])
        total += per_round
        cli.flush()
        _settle([cli])
    elapsed = time.perf_counter() - t0
    gc.enable()
    return total / elapsed


def load_json() -> dict:
    if os.path.exists(JSON_PATH):
        with open(JSON_PATH) as fh:
            return json.load(fh)
    return {"schema": 1, "configs": CONFIGS}


def save_json(doc: dict) -> None:
    with open(JSON_PATH, "w") as fh:
        json.dump(doc, fh, indent=2, sort_keys=True)
        fh.write("\n")


def main() -> None:
    argv = sys.argv[1:]
    smoke = ("--smoke" in argv
             or os.environ.get("DDS_BENCH_SMOKE", "0") == "1")
    record = ("baseline" if "--record-baseline" in argv else
              "current" if "--record-current" in argv else None)
    mode = "smoke" if smoke else "full"
    cfg = CONFIGS[mode]

    section(f"scale-out scheduler ({mode}: {cfg['shards']} shards, "
            f"{cfg['clients']} clients, {cfg['rounds']} settle-rounds, "
            f"Zipf a={cfg['zipf_a']} over {cfg['hot_keys']} hot keys)")
    # Noise strategy: identical to fig_writepath — every workload rep is
    # PAIRED with the calibration measured right around it (max of
    # before/after) and the best *normalized* rep wins, which controls for
    # mid-run CPU throttling; the committed number stays an
    # (ops, calibration) pair from one moment in time.
    reps = 2 if smoke else 6
    calib, res = 0.0, None
    c_before = calibrate()
    for _ in range(reps):
        r = run_zipf_workload(cfg)
        c_after = calibrate()
        c = max(c_before, c_after)
        if res is None or r["ops_per_s"] / c > res["ops_per_s"] / calib:
            calib, res = c, r
        c_before = c_after
    emit(f"scaleout_{mode}", 1e6 / res["ops_per_s"],
         f"tput={res['ops_per_s']:.0f}op/s "
         f"modeled={res['modeled_us_per_req']:.2f}us/req "
         f"dpu_gets={res['dpu_get_frac']:.2f}")

    idle_ratio = None
    if cfg["idle_rounds"]:
        # The machine-noise floor swings single measurements by 2x, so the
        # criterion is the MEDIAN of three interleaved (1-shard, 16-shard)
        # ratio pairs — each ratio compares two runs seconds apart, and the
        # median discards a pair that straddled a throttling event.
        ratios = []
        for _ in range(3):
            one = run_idle_workload(cfg, 1)
            wide = run_idle_workload(cfg, cfg["shards"])
            ratios.append(wide / one)
        idle_ratio = sorted(ratios)[1]
        res["idle_parity"] = round(idle_ratio, 3)
        emit("scaleout_idle_parity", idle_ratio,
             f"1-of-{cfg['shards']}-shard traffic at "
             f"{idle_ratio:.2f}x the 1-shard rate "
             f"(median of {[round(r, 2) for r in ratios]})")

    doc = load_json()
    doc["configs"] = CONFIGS
    res = {**res, "config": cfg}   # pin the workload the numbers came from
    entry = {"calibration_ops_per_s": calib, mode: res}
    if record:
        doc.setdefault(record, {})["calibration_ops_per_s"] = calib
        doc[record][mode] = res
        print(f"# recorded {mode} measurement into '{record}'")
    doc["last_run"] = {"mode": mode, **entry}
    base, cur = doc.get("baseline", {}), doc.get("current", {})
    if base.get("full") and cur.get("full"):
        b = base["full"]["ops_per_s"] / base["calibration_ops_per_s"]
        c = cur["full"]["ops_per_s"] / cur["calibration_ops_per_s"]
        doc["speedup_full_calibrated"] = round(c / b, 3)
        doc["speedup_full_raw"] = round(cur["full"]["ops_per_s"]
                                        / base["full"]["ops_per_s"], 3)
    save_json(doc)

    def gate_ref(sec: dict, which: str):
        """Recorded numbers are only comparable on the SAME workload."""
        ref = sec.get(which)
        if ref and ref.get("config") != cfg:
            print(f"# recorded {which} numbers used a different workload "
                  f"config; gate skipped — re-record with the new config")
            return None
        return ref

    failures = []

    def check_modeled(ref: dict) -> None:
        """Modeled time is the physics; the scheduler must not move it."""
        b, c = ref["modeled_us_per_req"], res["modeled_us_per_req"]
        if abs(c - b) > MODELED_DRIFT * b:
            failures.append(
                f"modeled us/req drifted: {c:.3f} vs recorded {b:.3f}")

    if not smoke and not record:
        ref = gate_ref(doc.get("baseline", {}), "full")
        if ref:
            scale = calib / doc["baseline"]["calibration_ops_per_s"]
            target = ref["ops_per_s"] * scale * FULL_SPEEDUP_GATE
            ok = res["ops_per_s"] >= target
            print(f"# speedup vs baseline (calibrated): "
                  f"{res['ops_per_s'] / (ref['ops_per_s'] * scale):.2f}x "
                  f"(gate {FULL_SPEEDUP_GATE:.1f}x) -> {'OK' if ok else 'FAIL'}")
            if not ok:
                failures.append(
                    f"scale-out below {FULL_SPEEDUP_GATE}x baseline: "
                    f"{res['ops_per_s']:.0f} < {target:.0f} op/s")
            check_modeled(ref)
        else:
            print("# no recorded baseline; gate skipped")
        if idle_ratio is not None and idle_ratio < IDLE_PARITY_GATE:
            failures.append(
                f"idle-cost criterion failed: single-shard traffic on "
                f"{cfg['shards']} shards at {idle_ratio:.2f}x the 1-shard "
                f"rate (gate {IDLE_PARITY_GATE:.2f}x)")
    if smoke and not record:
        ref = gate_ref(doc.get("current", {}), "smoke")
        if ref:
            scale = calib / doc["current"]["calibration_ops_per_s"]
            target = ref["ops_per_s"] * scale * SMOKE_REGRESSION_GATE
            ok = res["ops_per_s"] >= target
            print(f"# smoke vs recorded current (calibrated): "
                  f"{res['ops_per_s'] / (ref['ops_per_s'] * scale):.2f}x "
                  f"(gate {SMOKE_REGRESSION_GATE:.2f}x) -> "
                  f"{'OK' if ok else 'FAIL'}")
            if not ok:
                failures.append(
                    f"scale-out regressed >30% vs recorded current: "
                    f"{res['ops_per_s']:.0f} < {target:.0f} op/s")
            check_modeled(ref)
        else:
            print("# no recorded current numbers; gate skipped")
    if failures:
        raise RuntimeError("; ".join(failures))


if __name__ == "__main__":
    main()
