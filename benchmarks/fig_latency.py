"""Measured tail latency: per-request completion-tick distributions.

The paper's headline claim is LATENCY — offloaded reads complete in 780 us
vs 11 ms on the host path (§8, Figs 14a/15a) — yet the other executable
gates (hotpath/writepath/scaleout) measure only throughput.  This benchmark
measures latency the only way a cooperative simulator can do reproducibly:
in deterministic TICKS of the cluster scheduling clock (one tick per
``DDSCluster.pump``; see ``repro.core.lifecycle`` and README "Measured tail
latency").

The workload is OPEN-LOOP (fixed arrivals per tick, not closed-loop): every
tick, a fixed number of offloaded GETs and host-path writes are issued into
an 8-shard cluster whose devices have a bounded per-poll completion budget.
Writes arrive with periodic bursts — the §8.1 disaggregation scenario where
host-path write runs contend with latency-critical reads for the device.
The driver stamps each request at issue and at response drain, entirely at
the client, so THE SAME measurement runs against any tree (pre- and
post-overhaul); tick histograms are exact integers and two same-seed runs
are byte-identical (gated).

What the pre-PR tree shows: GETs queue FIFO behind write bursts at the
device, so GET p99 rides the write backlog.  Post-overhaul, offloaded reads
ride the device PRIORITY queue (with a bounded write-interleave share),
write coalescing/delivery flush on tick budgets, and the pump drains in
bounded slices — GET p99 collapses to the no-contention floor while writes
stay within their starvation bound.

Gates (all tick comparisons are machine-independent):

  * full: measured offloaded-GET p99 must be >= ``GET_P99_GATE`` (2.0x)
    LOWER than the committed pre-PR baseline; latency must not be bought
    with throughput — requests served per scheduling tick must stay
    >= 0.9x baseline (deterministic) with calibrated wall-clock ops/sec
    above a noise-floor backstop (see the gate constants); and all
    same-seed runs must produce IDENTICAL histograms;
  * --smoke (CI): fails when GET p99 regresses >30% vs the committed
    ``current`` ticks, or determinism breaks.

Results go to ``BENCH_latency.json`` (baseline recorded with
``--record-baseline`` on the pre-PR tree; current with ``--record-current``).
"""

from __future__ import annotations

import gc
import json
import os
import random
import struct
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.common import emit, section  # noqa: E402
from repro.core.client import ClusterClient  # noqa: E402
from repro.core.dds_server import ServerConfig  # noqa: E402
from repro.distributed.cluster import DDSCluster  # noqa: E402

JSON_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_latency.json")

GET_P99_GATE = 2.0        # offloaded-GET p99 must drop >= 2x vs baseline
# Throughput must not pay for latency.  The HARD 0.9x criterion is gated in
# the deterministic tick domain (requests served per scheduling tick —
# exact, machine-independent: more ticks per request would mean the
# scheduler's service rate was sacrificed).  Wall-clock calibrated ops/sec
# is ALSO gated, but at a noise floor: paired same-window runs measure the
# overhaul at 0.91-0.97x, while the cross-recording measurement error on
# shared/throttled machines is +-25% even after calibration — a hard 0.9x
# wall gate would be a coin flip, so it backstops gross regressions only.
OPS_PER_TICK_GATE = 0.9
OPS_WALL_FLOOR = 0.6
SMOKE_P99_REGRESSION = 1.3  # CI: fail when GET p99 grows >30% vs current

CONFIGS = {
    "full": dict(shards=8, clients=2, read_files=64, write_files=8,
                 ticks=256, warmup=32, reads_per_tick=48, steady_writes=16,
                 burst_writes=512, burst_every=8, read_size=256,
                 write_size=256, queue_depth=16, seed=7),
    "smoke": dict(shards=4, clients=2, read_files=32, write_files=4,
                  ticks=96, warmup=16, reads_per_tick=24, steady_writes=8,
                  burst_writes=256, burst_every=8, read_size=256,
                  write_size=256, queue_depth=16, seed=7),
}


def calibrate(iters: int = 200_000) -> float:
    """Reference ops/sec of a fixed pure-Python loop (machine-speed proxy)."""
    pack = struct.Struct("<QII").pack
    blob = bytes(range(256)) * 8
    t0 = time.perf_counter()
    d: dict[int, bytes] = {}
    for i in range(iters):
        d[i & 1023] = blob[i & 255 : (i & 255) + 64]
        pack(i, i & 0xFFFF, 64)
    return iters / (time.perf_counter() - t0)


def percentile(hist: dict[int, int], p: float) -> int:
    """Exact percentile of an integer-delta histogram."""
    n = sum(hist.values())
    if not n:
        return 0
    need = -(-n * p // 100)
    cum = 0
    d = 0
    for d in sorted(hist):
        cum += hist[d]
        if cum >= need:
            return d
    return d


def hist_doc(hist: dict[int, int]) -> dict:
    """JSON-stable exact histogram + summary."""
    return {
        "counts": {str(d): hist[d] for d in sorted(hist)},
        "count": sum(hist.values()),
        "p50": percentile(hist, 50),
        "p95": percentile(hist, 95),
        "p99": percentile(hist, 99),
        "max": max(hist) if hist else 0,
    }


def run_workload(cfg: dict) -> dict:
    """Open-loop mixed GET/write drive; returns tick histograms + rates."""
    cluster = DDSCluster(num_shards=cfg["shards"],
                         config=ServerConfig(device_capacity=1 << 26,
                                             cache_items=1 << 11))
    for srv in cluster.servers:
        # Bounded per-poll completion budget: the device services a finite
        # number of ops per scheduling step, so queueing is observable in
        # ticks.  Set directly (works against pre-overhaul trees too).
        srv.device.queue_depth = cfg["queue_depth"]
    span = 1 << 16
    read_files = [cluster.create_file(f"lat-r{i}")
                  for i in range(cfg["read_files"])]
    write_files = [cluster.create_file(f"lat-w{i}")
                   for i in range(cfg["write_files"])]
    for i, f in enumerate(read_files):
        cluster.write_sync(f, 0, bytes([i & 0xFF]) * span)
    for f in write_files:
        cluster.write_sync(f, 0, b"\x00" * span)
    # FIXED ports: run-to-run identical flows => identical histograms.
    clients = [ClusterClient(cluster, port=46000 + 100 * i)
               for i in range(cfg["clients"])]
    rng = random.Random(cfg["seed"])
    rsize, wsize = cfg["read_size"], cfg["write_size"]
    payload = b"w" * wsize
    # Keyed by (client, rid): each client has its OWN rid space.
    issued: dict[tuple, tuple[int, str]] = {}
    hist = {"get": {}, "write": {}}
    tick = 0
    n_reads = n_writes = 0

    def harvest(ci, cli) -> None:
        resp = cli.responses
        while resp:
            rid, (status, _body) = resp.popitem()
            assert status == 0, f"request {rid} failed with status {status}"
            ent = issued.pop((ci, rid), None)
            if ent is None:
                continue
            t_iss, cls = ent
            if t_iss >= 0:             # warmup requests carry -1: untimed
                h = hist[cls]
                d = tick - t_iss
                h[d] = h.get(d, 0) + 1

    total_ticks = cfg["warmup"] + cfg["ticks"]
    gc.collect()
    gc.disable()
    t0 = time.perf_counter()
    for t in range(total_ticks):
        rec = t >= cfg["warmup"]
        stamp = tick if rec else -1
        reads = [(read_files[rng.randrange(len(read_files))],
                  rng.randrange(0, span - rsize), rsize)
                 for _ in range(cfg["reads_per_tick"])]
        wn = cfg["steady_writes"] + (cfg["burst_writes"]
                                     if t % cfg["burst_every"] == 0 else 0)
        # 1 KiB-strided offsets: consecutive writes land non-adjacent, so
        # runs do not coalesce away — the device sees one op per write.
        writes = [(write_files[rng.randrange(len(write_files))],
                   (rng.randrange(0, (span - wsize) // 1024) * 1024 + 512)
                   % (span - wsize), payload)
                  for _ in range(wn)]
        # Contiguous per-client chunks (the last client takes the tail) —
        # generalizes to any client count without reshuffling the 2-client
        # split the committed baselines were recorded with.
        nc = len(clients)
        chunk_r, chunk_w = len(reads) // nc, len(writes) // nc
        for ci, cli in enumerate(clients):
            r_end = (ci + 1) * chunk_r if ci < nc - 1 else len(reads)
            w_end = (ci + 1) * chunk_w if ci < nc - 1 else len(writes)
            rr = reads[ci * chunk_r : r_end]
            ww = writes[ci * chunk_w : w_end]
            for rid in cli.read_many(rr):
                issued[(ci, rid)] = (stamp, "get")
            for rid in cli.write_many(ww):
                issued[(ci, rid)] = (stamp, "write")
            if rec:
                n_reads += len(rr)
                n_writes += len(ww)
            cli.flush()
        cluster.pump()      # ONE scheduling step == one tick (open loop)
        tick += 1
        for ci, cli in enumerate(clients):
            cli.poll()
            harvest(ci, cli)
    # Drain: arrivals stop; keep ticking until every request is answered.
    for _ in range(200_000):
        if not issued:
            break
        work = cluster.pump()
        tick += 1
        for ci, cli in enumerate(clients):
            cli.poll()
            harvest(ci, cli)
        if work == 0:
            for srv in cluster.servers:
                srv.device.drain()
    elapsed = time.perf_counter() - t0
    gc.enable()
    assert not issued, f"{len(issued)} requests never completed"

    total = n_reads + n_writes
    offloaded = sum(s.offload.stats.completed for s in cluster.servers)
    bounced = sum(s.offload.stats.bounced_to_host for s in cluster.servers)
    # Every GET must be DPU-served, or "GET" ticks would mix serving paths.
    assert bounced == 0, f"{bounced} reads bounced to host; retune workload"
    got_gets = sum(hist["get"].values())
    assert got_gets == n_reads, f"harvested {got_gets}/{n_reads} GETs"
    res = {
        "requests": total,
        "reads": n_reads,
        "writes": n_writes,
        "ticks": tick,
        "wall_s": elapsed,
        "ops_per_s": total / elapsed,
        "get": hist_doc(hist["get"]),
        "write": hist_doc(hist["write"]),
    }
    # Post-overhaul trees also expose server-side lifecycle histograms;
    # cross-check the counts (the distributions measure different segments:
    # ingress->publish vs issue->drain).
    if hasattr(cluster, "latency_stats"):
        stats = cluster.latency_stats()
        dpu = stats.get("classes", {}).get("dpu_read", {})
        assert dpu.get("count", 0) >= n_reads, \
            f"server-side dpu_read count {dpu} < driver reads {n_reads}"
        res["server"] = stats
    return res


def load_json() -> dict:
    if os.path.exists(JSON_PATH):
        with open(JSON_PATH) as fh:
            return json.load(fh)
    return {"schema": 1, "configs": CONFIGS}


def save_json(doc: dict) -> None:
    with open(JSON_PATH, "w") as fh:
        json.dump(doc, fh, indent=2, sort_keys=True)
        fh.write("\n")


def main() -> None:
    argv = sys.argv[1:]
    smoke = ("--smoke" in argv
             or os.environ.get("DDS_BENCH_SMOKE", "0") == "1")
    record = ("baseline" if "--record-baseline" in argv else
              "current" if "--record-current" in argv else None)
    mode = "smoke" if smoke else "full"
    cfg = CONFIGS[mode]

    section(f"tail latency ({mode}: {cfg['shards']} shards, open-loop "
            f"{cfg['reads_per_tick']} GET/tick + {cfg['steady_writes']}"
            f"+{cfg['burst_writes']}/{cfg['burst_every']} writes/tick, "
            f"{cfg['ticks']} ticks)")
    # Same-seed reps: determinism gate AND noise reduction.  Tick
    # histograms must be identical across reps; for wall-clock, each rep's
    # ops/sec is paired with the MEAN of its two surrounding calibrations
    # (the best local estimate of machine speed during that rep — shared
    # machines throttle in phases, so a global calibration is unfair) and
    # the best normalized rep is gated.
    reps = []
    calib = 0.0
    for _ in range(2 if smoke else 3):
        c1 = calibrate()
        r = run_workload(cfg)
        c2 = calibrate()
        calib = max(calib, c1, c2)
        r["ops_norm"] = r["ops_per_s"] / ((c1 + c2) / 2)
        reps.append(r)
    identical = all(r["get"]["counts"] == reps[0]["get"]["counts"]
                    and r["write"]["counts"] == reps[0]["write"]["counts"]
                    for r in reps[1:])
    res = max(reps, key=lambda r: r["ops_norm"])
    g, w = res["get"], res["write"]
    emit(f"latency_{mode}", 1e6 / res["ops_per_s"],
         f"get_p50={g['p50']}t get_p99={g['p99']}t write_p99={w['p99']}t "
         f"tput={res['ops_per_s']:.0f}op/s deterministic={identical}")

    doc = load_json()
    doc["configs"] = CONFIGS
    res = {k: v for k, v in res.items() if k != "server"}
    res["config"] = cfg
    res["deterministic"] = identical
    entry = {"calibration_ops_per_s": calib, mode: res}
    if record:
        doc.setdefault(record, {})["calibration_ops_per_s"] = calib
        doc[record][mode] = res
        print(f"# recorded {mode} measurement into '{record}'")
    doc["last_run"] = {"mode": mode, **entry}
    base, cur = doc.get("baseline", {}), doc.get("current", {})
    if base.get("full") and cur.get("full"):
        doc["get_p99_improvement"] = round(
            base["full"]["get"]["p99"] / max(cur["full"]["get"]["p99"], 1), 3)
    save_json(doc)

    def gate_ref(section_doc: dict, which: str):
        ref = section_doc.get(which)
        if ref and ref.get("config") != cfg:
            print(f"# recorded {which} numbers used a different workload "
                  f"config; gate skipped — re-record with the new config")
            return None
        return ref

    failures = []
    if not identical:
        failures.append("two same-seed runs produced different histograms "
                        "(determinism gate)")
    if not smoke and not record:
        ref = gate_ref(base, "full")
        if ref:
            base_p99 = ref["get"]["p99"]
            cur_p99 = max(g["p99"], 1)
            ratio = base_p99 / cur_p99
            ok = ratio >= GET_P99_GATE
            print(f"# offloaded-GET p99: {base_p99} -> {g['p99']} ticks "
                  f"({ratio:.2f}x lower; gate {GET_P99_GATE:.1f}x) -> "
                  f"{'OK' if ok else 'FAIL'}")
            if not ok:
                failures.append(
                    f"GET p99 not {GET_P99_GATE}x lower than baseline: "
                    f"{g['p99']} vs {base_p99} ticks")
            # Deterministic throughput criterion: requests per scheduling
            # tick (exact on both sides — no calibration involved).
            rpt_base = ref["requests"] / ref["ticks"]
            rpt_cur = res["requests"] / res["ticks"]
            ratio_rpt = rpt_cur / rpt_base
            rpt_ok = ratio_rpt >= OPS_PER_TICK_GATE
            print(f"# requests/tick vs baseline (deterministic): "
                  f"{rpt_cur:.1f} vs {rpt_base:.1f} ({ratio_rpt:.2f}x; "
                  f"gate {OPS_PER_TICK_GATE:.2f}x) -> "
                  f"{'OK' if rpt_ok else 'FAIL'}")
            if not rpt_ok:
                failures.append(
                    f"latency must not be bought with throughput: "
                    f"{ratio_rpt:.2f}x < {OPS_PER_TICK_GATE:.2f}x "
                    f"requests/tick vs baseline")
            # Wall-clock backstop (noise floor; see OPS_WALL_FLOOR note).
            ratio_ops = res["ops_norm"] / ref["ops_norm"]
            ops_ok = ratio_ops >= OPS_WALL_FLOOR
            print(f"# ops/sec vs baseline (calibrated wall-clock, "
                  f"noise-floor backstop): {ratio_ops:.2f}x "
                  f"(floor {OPS_WALL_FLOOR:.2f}x) -> "
                  f"{'OK' if ops_ok else 'FAIL'}")
            if not ops_ok:
                failures.append(
                    f"wall-clock collapsed: {ratio_ops:.2f}x < "
                    f"{OPS_WALL_FLOOR:.2f}x calibrated ops/sec vs baseline")
        else:
            print("# no recorded baseline; gate skipped")
    if smoke and not record:
        ref = gate_ref(cur, "smoke")
        if ref:
            limit = ref["get"]["p99"] * SMOKE_P99_REGRESSION
            ok = g["p99"] <= limit
            print(f"# smoke GET p99 vs recorded current: {g['p99']} vs "
                  f"{ref['get']['p99']} ticks (limit {limit:.1f}) -> "
                  f"{'OK' if ok else 'FAIL'}")
            if not ok:
                failures.append(
                    f"GET p99 regressed >30% vs recorded current: "
                    f"{g['p99']} > {limit:.1f} ticks")
        else:
            print("# no recorded current numbers; gate skipped")
    if failures:
        raise RuntimeError("; ".join(failures))


if __name__ == "__main__":
    main()
