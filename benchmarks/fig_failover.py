"""Kill-a-shard failover gate: zero lost acks, bounded blip, cheap replicas.

PR 7 adds replication groups (primary + K ring successors), primary-backup
forwarding over the host wire (a write ack releases only after every
replica holds the bytes), tick-clock heartbeat detection, replica
promotion with ring repair, and an epoch fence that turns a crash into
transparent client-side replay.  This benchmark holds that whole stack to
the paper's §8.1 availability story under the fig_scaleout-style workload:
a Zipfian-skewed sharded-KV read-modify-write loop, except here the shard
that owns the HOTTEST key is killed mid-run.

One scenario, three measurements — all in deterministic TICKS of the
shared cluster clock, so every gate is machine-independent:

  * **zero lost acknowledged writes** — every PUT the client saw ack is
    re-read and byte-compared after failover (inline every round AND in a
    final sweep).  K=1, one crash: nothing acked may vanish.  Hard gate,
    any mode.
  * **bounded p99 blip** — per-round settle times are recorded in ticks;
    the crash round is allowed the heartbeat timeout plus a fixed
    promotion allowance on top of the steady-state p99, and post-failover
    rounds must return to (near) the steady-state p99 even though the
    promoted shard now serves two shards' heat.
  * **replication is cheap** — the same workload runs on an unreplicated
    cluster (K=0, no crash); the replicated run's steady-state ops/tick
    must stay >= ``TPUT_GATE`` (0.9x) of it.  Write acks wait for the
    replica, so this bounds the ack-hold pipeline cost.

Two same-seed replicated runs must produce IDENTICAL round-tick traces,
failover events and ack ledgers (determinism gate).  Wall-clock ops/sec is
reported (calibrated) but never gated — the tick domain carries the
contract.  Results go to ``BENCH_failover.json``; ``--smoke`` (CI) runs a
reduced config and additionally fails on a >30% tick regression vs the
committed ``current`` numbers.
"""

from __future__ import annotations

import gc
import json
import os
import struct
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.common import emit, section  # noqa: E402
from repro.apps.kv_store import KVClient, ShardedKVStore, decode_record  # noqa: E402
from repro.core import wire  # noqa: E402
from repro.core.dds_server import ServerConfig  # noqa: E402

JSON_PATH = os.path.join(os.path.dirname(__file__), "..",
                         "BENCH_failover.json")

TPUT_GATE = 0.9         # replicated steady ops/tick >= 0.9x unreplicated
BLIP_SLACK = 24         # crash-round allowance beyond timeout + steady p99
RECOVERY_SLACK = 8      # post-failover round p99 may exceed steady p99 by
                        # this many ticks (promoted shard serves 2x heat)
SMOKE_REGRESSION = 1.3  # CI: fail when blip/steady ticks grow >30% vs current

CONFIGS = {
    "full": dict(shards=8, clients=2, hot_keys=64, zipf_a=2.5, rounds=32,
                 crash_round=16, gets=144, overwrites=48, value_size=64,
                 queue_depth=4, heartbeat_timeout_ticks=8),
    "smoke": dict(shards=4, clients=2, hot_keys=24, zipf_a=2.5, rounds=12,
                  crash_round=6, gets=144, overwrites=48, value_size=64,
                  queue_depth=4, heartbeat_timeout_ticks=6),
}

ZIPF_SEED = 0xFA110


def calibrate(iters: int = 200_000) -> float:
    """Reference ops/sec of a fixed pure-Python loop (machine-speed proxy)."""
    pack = struct.Struct("<QII").pack
    blob = bytes(range(256)) * 8
    t0 = time.perf_counter()
    d: dict[int, bytes] = {}
    for i in range(iters):
        d[i & 1023] = blob[i & 255 : (i & 255) + 64]
        pack(i, i & 0xFFFF, 64)
    return iters / (time.perf_counter() - t0)


def percentile(vals: list[int], p: float) -> int:
    """Exact percentile of a small integer sample (nearest-rank)."""
    if not vals:
        return 0
    s = sorted(vals)
    return s[min(len(s) - 1, -(-len(s) * int(p) // 100) - 1)]


def _zipf_ranks(cfg: dict, total: int) -> list[int]:
    """Seeded skewed rank sequence, precomputed (untimed): the exact same
    key sequence every rep, every run, every machine."""
    rng = np.random.default_rng(ZIPF_SEED)
    return [(int(z) - 1) % cfg["hot_keys"]
            for z in rng.zipf(cfg["zipf_a"], size=total)]


def _value(key: bytes, rnd: int, size: int) -> bytes:
    """Round-stamped value, a function of (key, round) ONLY — two clients
    overwriting the same key in the same round agree on the bytes, so the
    acked ledger is unambiguous."""
    base = key + b"#%05d#" % rnd
    return (base * (size // len(base) + 1))[:size]


def run_failover_workload(cfg: dict, replication: int, crash: bool) -> dict:
    """Drive the settle-per-round Zipfian RMW loop; optionally kill the
    shard that owns the hottest key mid-run.  Returns tick-domain results
    plus the acked-write ledger verification."""
    config = ServerConfig(device_capacity=1 << 26, cache_items=1 << 14,
                          replication=replication,
                          heartbeat_timeout_ticks=cfg[
                              "heartbeat_timeout_ticks"])
    store = ShardedKVStore(num_shards=cfg["shards"], config=config)
    cluster = store.cluster
    for srv in cluster.servers:
        # Bounded per-poll completion budget (as in fig_latency): rounds
        # are limited by device service rate, not pipeline depth, so the
        # workload is THROUGHPUT-bound and the replica hop has queueing to
        # hide behind — the regime the 0.9x replication-cost gate is about.
        srv.device.queue_depth = cfg["queue_depth"]
    clients = [KVClient(store) for _ in range(cfg["clients"])]
    vsize = cfg["value_size"]
    hot = [b"hot-%04d" % i for i in range(cfg["hot_keys"])]

    # Untimed warm: PUT-ack every hot key (arms the DPU cache, seeds the
    # acked ledger) through client 0.
    acked: dict[bytes, bytes] = {}
    rids = clients[0].submit([("put", k, _value(k, -1, vsize)) for k in hot])
    res = clients[0].harvest(rids)
    assert all(s == wire.E_OK for s, _ in res.values())
    for k in hot:
        acked[k] = _value(k, -1, vsize)
    res = clients[0].harvest(clients[0].submit([("get", k) for k in hot]))
    assert all(s == wire.E_OK for s, _ in res.values())
    for cli in clients:
        cli.net.run_until_idle()

    per_round = cfg["gets"] + cfg["overwrites"]
    ranks = _zipf_ranks(cfg, cfg["rounds"] * cfg["clients"] * per_round)
    rk = iter(ranks)
    round_ticks: list[int] = []
    lost = 0
    total = 0
    victim = promoted = None
    gc.collect()
    gc.disable()   # keep collector pauses out of the timed region
    t0 = time.perf_counter()
    for r in range(cfg["rounds"]):
        if crash and r == cfg["crash_round"]:
            # Kill the shard that owns the hottest key, two ticks into the
            # round — mid-GET-burst, the worst moment for it to die.
            victim = store.shard_for_key(hot[0])
            cluster.crash_at(victim, cluster.clock.now + 2)
        t_start = cluster.clock.now
        # Read phase: every client GETs its Zipf-ranked keys and BLOCKS on
        # the values; each value is byte-compared against the acked ledger
        # (a failover in the middle must not surface stale or lost bytes).
        gmeta = []
        for cli in clients:
            ks = [hot[next(rk)] for _ in range(cfg["gets"])]
            gmeta.append((cli, ks, cli.submit([("get", k) for k in ks])))
        for cli, ks, rg in gmeta:
            res = cli.harvest(rg)
            for k, rid in zip(ks, rg):
                status, body = res[rid]
                if status != wire.E_OK or decode_record(body)[1] != acked[k]:
                    lost += 1
        # Modify phase: overwrite-PUT hot keys; an E_OK harvest updates the
        # ledger — from that moment the bytes must survive any crash.
        pmeta = []
        for cli in clients:
            ks = [hot[next(rk)] for _ in range(cfg["overwrites"])]
            pmeta.append((cli, ks, cli.submit(
                [("put", k, _value(k, r, vsize)) for k in ks])))
        for cli, ks, rp in pmeta:
            res = cli.harvest(rp)
            for k, rid in zip(ks, rp):
                if res[rid][0] == wire.E_OK:
                    acked[k] = _value(k, r, vsize)
                else:
                    lost += 1
        for cli in clients:
            cli.net.run_until_idle()
        total += cfg["clients"] * per_round
        round_ticks.append(cluster.clock.now - t_start)
    # Make sure a scheduled kill whose round outran it still lands, then
    # sweep the WHOLE ledger: every byte ever acked must be readable.
    if crash and victim is not None and not cluster.failover_events:
        # detection = miss_windows (2) consecutive silent windows
        deadline = (cluster.clock.now
                    + 2 * (cfg["heartbeat_timeout_ticks"] + 1) + 5)
        while cluster.clock.now < deadline:
            cluster.pump()
    sweep = clients[0].submit([("get", k) for k in hot])
    res = clients[0].harvest(sweep)
    for k, rid in zip(hot, sweep):
        status, body = res[rid]
        if status != wire.E_OK or decode_record(body)[1] != acked[k]:
            lost += 1
    elapsed = time.perf_counter() - t0
    gc.enable()

    cr = cfg["crash_round"]
    steady = round_ticks[:cr]
    post = round_ticks[cr + 1:]
    if crash:
        events = cluster.failover_events
        assert len(events) == 1 and events[0]["dead"] == victim, events
        promoted = events[0]["promoted"]
    stats = cluster.latency_stats()
    out = {
        "requests": total,
        "ticks": cluster.clock.now,
        "wall_s": elapsed,
        "ops_per_s": total / elapsed,
        "lost_acked": lost,
        "round_ticks": round_ticks,
        "steady_ops_per_tick": (cr * cfg["clients"] * per_round
                                / max(sum(steady), 1)),
        "steady_p99": percentile(steady, 99),
        "blip_ticks": round_ticks[cr] if crash else 0,
        "post_p99": percentile(post, 99) if crash else 0,
    }
    if crash:
        out["failover"] = {"victim": victim, "promoted": promoted,
                           "events": list(cluster.failover_events)}
        out["replication"] = stats.get("replication", {})
    return out


def load_json() -> dict:
    if os.path.exists(JSON_PATH):
        with open(JSON_PATH) as fh:
            return json.load(fh)
    return {"schema": 1, "configs": CONFIGS}


def save_json(doc: dict) -> None:
    with open(JSON_PATH, "w") as fh:
        json.dump(doc, fh, indent=2, sort_keys=True)
        fh.write("\n")


def main() -> None:
    argv = sys.argv[1:]
    smoke = ("--smoke" in argv
             or os.environ.get("DDS_BENCH_SMOKE", "0") == "1")
    record = ("current" if "--record-current" in argv else None)
    mode = "smoke" if smoke else "full"
    cfg = CONFIGS[mode]

    section(f"kill-a-shard failover ({mode}: {cfg['shards']} shards K=1, "
            f"{cfg['clients']} clients, crash at round "
            f"{cfg['crash_round']}/{cfg['rounds']}, Zipf a={cfg['zipf_a']} "
            f"over {cfg['hot_keys']} hot keys)")
    # Two same-seed replicated runs (determinism gate) + one unreplicated
    # reference run for the replication-cost gate.  Wall-clock is paired
    # with surrounding calibrations for the report line only — every gate
    # below lives in the deterministic tick domain.
    c1 = calibrate()
    res = run_failover_workload(cfg, replication=1, crash=True)
    rep2 = run_failover_workload(cfg, replication=1, crash=True)
    plain = run_failover_workload(cfg, replication=0, crash=False)
    c2 = calibrate()
    calib = max(c1, c2)
    identical = all(res[k] == rep2[k] for k in
                    ("round_ticks", "failover", "lost_acked", "ticks",
                     "requests"))
    tput_ratio = (res["steady_ops_per_tick"]
                  / max(plain["steady_ops_per_tick"], 1e-9))
    emit(f"failover_{mode}", float(res["blip_ticks"]),
         f"lost_acked={res['lost_acked']} blip={res['blip_ticks']}t "
         f"steady_p99={res['steady_p99']}t post_p99={res['post_p99']}t "
         f"tput_ratio={tput_ratio:.2f}x deterministic={identical} "
         f"tput={res['ops_per_s']:.0f}op/s")
    repl = res.get("replication", {})
    if repl:
        lag = repl.get("lag", {})
        emit(f"failover_{mode}_replication", float(lag.get("p99", 0)),
             f"forwarded={repl.get('forwarded', 0)} "
             f"bytes={repl.get('bytes', 0)} lag_p99={lag.get('p99', 0)}t")

    doc = load_json()
    doc["configs"] = CONFIGS
    res = {k: v for k, v in res.items() if k != "round_ticks"}
    res["config"] = cfg
    res["deterministic"] = identical
    res["tput_ratio_vs_unreplicated"] = round(tput_ratio, 3)
    res["unreplicated_steady_ops_per_tick"] = round(
        plain["steady_ops_per_tick"], 3)
    entry = {"calibration_ops_per_s": calib, mode: res}
    if record:
        doc.setdefault("current", {})["calibration_ops_per_s"] = calib
        doc["current"][mode] = res
        print(f"# recorded {mode} measurement into 'current'")
    doc["last_run"] = {"mode": mode, **entry}
    save_json(doc)

    failures = []
    if res["lost_acked"]:
        failures.append(f"{res['lost_acked']} acknowledged writes lost or "
                        f"stale after failover (gate: zero)")
    if not identical:
        failures.append("two same-seed runs diverged (round ticks, "
                        "failover events or ledger) — determinism gate")
    detect = 2 * (cfg["heartbeat_timeout_ticks"] + 1)   # miss_windows = 2
    blip_limit = res["steady_p99"] + detect + BLIP_SLACK
    ok = res["blip_ticks"] <= blip_limit
    print(f"# crash-round blip: {res['blip_ticks']}t (steady p99 "
          f"{res['steady_p99']}t + detection {detect}t "
          f"+ slack {BLIP_SLACK}t = limit {blip_limit}t) -> "
          f"{'OK' if ok else 'FAIL'}")
    if not ok:
        failures.append(f"failover blip unbounded: {res['blip_ticks']} > "
                        f"{blip_limit} ticks")
    rec_limit = res["steady_p99"] + RECOVERY_SLACK
    ok = res["post_p99"] <= rec_limit
    print(f"# post-failover round p99: {res['post_p99']}t "
          f"(limit {rec_limit}t) -> {'OK' if ok else 'FAIL'}")
    if not ok:
        failures.append(f"post-failover p99 never recovered: "
                        f"{res['post_p99']} > {rec_limit} ticks")
    ok = tput_ratio >= TPUT_GATE
    print(f"# steady ops/tick, replicated vs unreplicated (deterministic): "
          f"{res['steady_ops_per_tick']:.2f} vs "
          f"{plain['steady_ops_per_tick']:.2f} ({tput_ratio:.2f}x; gate "
          f"{TPUT_GATE:.2f}x) -> {'OK' if ok else 'FAIL'}")
    if not ok:
        failures.append(f"replication too expensive: {tput_ratio:.2f}x < "
                        f"{TPUT_GATE:.2f}x unreplicated steady ops/tick")
    if smoke and not record:
        ref = doc.get("current", {}).get("smoke")
        if ref and ref.get("config") == cfg:
            for key in ("blip_ticks", "steady_p99"):
                limit = max(ref[key], 1) * SMOKE_REGRESSION
                if res[key] > limit:
                    failures.append(
                        f"{key} regressed >30% vs recorded current: "
                        f"{res[key]} > {limit:.1f} ticks")
            print(f"# smoke vs recorded current: blip {res['blip_ticks']}t "
                  f"vs {ref['blip_ticks']}t, steady p99 {res['steady_p99']}t "
                  f"vs {ref['steady_p99']}t")
        else:
            print("# no comparable recorded current numbers; "
                  "smoke regression gate skipped")
    if failures:
        raise RuntimeError("; ".join(failures))


if __name__ == "__main__":
    main()
