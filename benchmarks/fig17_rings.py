"""Fig 17: DMA ring-buffer designs under producer concurrency.

Python threads cannot reproduce BF-2 contention (the GIL serializes every
producer), so this benchmark separates what IS measurable from what must be
modeled:

  (a) MEASURED, deterministic: protocol costs per message as a function of
      batch size — DMA transactions (from DMAEngine's transaction counter)
      and atomic pointer operations — for each of the three designs.  These
      are properties of the implementations, not of the host.
  (b) MODELED: throughput vs producer count from those counts and hardware
      constants — 1.5 us per PCIe DMA transaction, ~100 ns per serialized
      atomic, and a lock-convoy factor for the lock ring calibrated to the
      paper's own two endpoints (22 M op/s at 1 producer -> 1.4 M at 64).
  (c) MEASURED wall rates on CPython threads (transparency only).

Expected (paper): progressive sustains ~6.5 M msg/s at 64 producers,
~4.5x the lock ring and ~10x (order-of-magnitude) the FaRM-style ring.
"""

from __future__ import annotations

import threading
import time

from benchmarks.common import emit, section
from repro.core.ring import (DMAEngine, FaRMStyleRing, LockRing,
                             ProgressiveRing, frame, unframe_batch, OK)

MSG = b"12345678"           # 8-byte messages (§8.5)
DMA_US = 1.5                # PCIe Gen4 DMA latency per transaction
ATOMIC_US = 0.1             # serialized CAS/fetch-add on contended line
LOCK_HOLD_US = 0.25         # pointer ops + 8B memcpy under the lock (C-level)
CONVOY = 0.236              # lock-convoy growth/producer (fits paper 22M->1.4M)


def protocol_costs(batch: int) -> dict[str, dict[str, float]]:
    """MEASURED per-message DMA + atomic ops when inserts arrive in
    ``batch``-sized bursts (deterministic single-thread protocol replay)."""
    out = {}
    # progressive
    ring = ProgressiveRing(1 << 16)
    dma = DMAEngine()
    for _ in range(batch):
        assert ring.try_insert(frame(MSG)) == OK
    ring._atom.ops = 0
    for _ in range(batch):
        ring.try_insert(frame(MSG))
    atomics = ring._atom.ops / batch
    b0 = dma.stats.snapshot()
    while ring.consume(dma) is not None:
        pass
    d = dma.stats.delta(b0)
    out["progressive"] = {"dma": (d.reads + d.writes) / (2 * batch),
                          "atomics": atomics}
    # lock ring
    ring = LockRing(1 << 16)
    dma = DMAEngine()
    for _ in range(batch):
        ring.try_insert(frame(MSG))
    b0 = dma.stats.snapshot()
    while ring.consume(dma) is not None:
        pass
    d = dma.stats.delta(b0)
    out["lock"] = {"dma": (d.reads + d.writes) / batch, "atomics": 0.0}
    # farm ring: poll-hit + payload read + release write per message, plus
    # one poll miss per drain attempt
    ring = FaRMStyleRing(slots=4096, slot_size=64)
    dma = DMAEngine()
    for _ in range(batch):
        ring.try_insert(MSG)
    b0 = dma.stats.snapshot()
    while ring.consume_one(dma) is not None:
        pass
    d = dma.stats.delta(b0)
    out["farm"] = {"dma": (d.reads + d.writes) / batch, "atomics": 1.0}
    return out


def modeled_rate(design: str, costs: dict, producers: int) -> float:
    """Messages/s bounded by the slower of the DMA engine and producer
    serialization."""
    dma_us = costs["dma"] * DMA_US
    if design == "lock":
        serial_us = LOCK_HOLD_US * (1.0 + CONVOY * (producers - 1))
    else:
        serial_us = costs["atomics"] * ATOMIC_US
    return 1e6 / max(dma_us, serial_us)


def wall_rates(producers: int) -> dict[str, float]:
    """CPython wall rates (GIL-bound; transparency only)."""
    out = {}
    for name, mk, consume in (
            ("progressive", lambda: ProgressiveRing(1 << 16),
             lambda r, d: len(unframe_batch(b)) if (b := r.consume(d)) else 0),
            ("lock", lambda: LockRing(1 << 16),
             lambda r, d: len(unframe_batch(b)) if (b := r.consume(d)) else 0),
            ("farm", lambda: FaRMStyleRing(slots=4096, slot_size=64),
             lambda r, d: 1 if r.consume_one(d) is not None else 0)):
        ring, dma = mk(), DMAEngine()
        total = producers * 1500
        got = {"n": 0}
        stop = threading.Event()

        def consumer():
            while got["n"] < total:
                n = consume(ring, dma)
                got["n"] += n
                if n == 0 and stop.is_set() and consume(ring, dma) == 0:
                    return

        def producer():
            msg = frame(MSG) if not isinstance(ring, FaRMStyleRing) else MSG
            for _ in range(1500):
                while ring.try_insert(msg) != OK:
                    # Ring full: yield the GIL so the consumer can drain.
                    # A bare spin makes this measure CPython's scheduler
                    # roulette (N spinners starving the one consumer), not
                    # the ring protocol.
                    time.sleep(0)

        t0 = time.perf_counter()
        ct = threading.Thread(target=consumer)
        ct.start()
        ps = [threading.Thread(target=producer) for _ in range(producers)]
        for p in ps:
            p.start()
        for p in ps:
            p.join()
        stop.set()
        ct.join(timeout=30)
        if got["n"] == 0:
            # GIL-starved consumer made no progress: report loudly and skip
            # rather than fabricating a rate (or crashing the nightly run).
            print(f"# fig17c_{name}: consumer starved (GIL); entry skipped")
            continue
        out[name] = got["n"] / (time.perf_counter() - t0)
    return out


def main() -> None:
    section("fig17a: protocol costs per message (measured, deterministic)")
    for batch in (1, 8, 64):
        costs = protocol_costs(batch)
        for name, c in costs.items():
            emit(f"fig17a_{name}_batch{batch}", c["dma"] * DMA_US,
                 f"dma_ops_per_msg={c['dma']:.3f} atomics={c['atomics']:.1f}")
    section("fig17b: modeled throughput vs producers (BF-2 constants)")
    results = {}
    for producers in (1, 4, 16, 64):
        batch = min(64, max(1, producers * 4))  # batching grows with load
        costs = protocol_costs(batch)
        for name in ("progressive", "lock", "farm"):
            r = modeled_rate(name, costs[name], producers)
            results[(name, producers)] = r
            emit(f"fig17b_{name}_p{producers}", 1e6 / r, f"{r / 1e6:.2f} M/s")
    for p in (64,):
        prog = results[("progressive", p)]
        emit(f"fig17b_speedup_vs_lock_p{p}", 0.0,
             f"{prog / results[('lock', p)]:.1f}x (paper: ~4.5x)")
        emit(f"fig17b_speedup_vs_farm_p{p}", 0.0,
             f"{prog / results[('farm', p)]:.1f}x (paper: ~10x; farm also "
             f"capped by per-slot PCIe polling)")
    section("fig17c: CPython wall rates (GIL-bound, transparency only)")
    for producers in (1, 8):
        for name, rate in wall_rates(producers).items():
            emit(f"fig17c_{name}_p{producers}", 1e6 / rate, f"{rate:,.0f}/s")


if __name__ == "__main__":
    main()
