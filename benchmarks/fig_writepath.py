"""Write-path ops/sec: the host-path data plane at CPython line rate.

PR 2 rebuilt the offloaded *read* path (``fig_hotpath`` runs at
``offloaded_frac: 1.0``); every write and cache-miss read still lands on the
host path: DMA rings -> file service -> block device -> response delivery.
This benchmark holds that path to the same standard with a **mixed,
write-heavy KV workload** on the sharded §9.2 store:

  * **PUT**  — host path end to end (request ring -> coalesced log append ->
    ack), firing ``Cache`` (cache-on-write) so later GETs offload;
  * **GET**  — only settled keys are fetched, so each GET is DPU-served from
    the cache table (the §6 fast path stays hot while writes dominate);
  * **DEL**  — host read-for-update, firing ``Invalidate``
    (invalidate-on-read churn through the cache table).

The driver pipelines rounds with depth 2 and only touches *settled* keys
(acked two rounds ago), so the host/DPU split — and therefore the modeled
per-request time — is fully deterministic: speedups must come from deleting
wall-clock overhead, never from re-routing work.

Results go to ``BENCH_writepath.json``.  Wall-clock numbers are calibrated
exactly like ``fig_hotpath``: a fixed pure-Python reference loop is timed
alongside, and committed numbers are rescaled by the machine-speed ratio
before any gate applies.  Sections: ``baseline`` (pre-overhaul, recorded
with ``--record-baseline``), ``current`` (``--record-current``),
``last_run`` (always rewritten).

Gates:

  * full mode asserts >= ``FULL_SPEEDUP_GATE`` (2.0x) calibrated ops/sec
    over the recorded baseline;
  * ``--smoke`` (CI fast lane) runs a reduced config and fails on a >30%
    calibrated regression vs the recorded ``current`` numbers;
  * both modes assert the zero-copy write invariant
    (``request_copies == 0``), that modeled time matches the recorded
    reference (the simulator got faster, not the model), and that every
    operation was answered.
"""

from __future__ import annotations

import gc
import json
import os
import struct
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.common import emit, section  # noqa: E402
from repro.apps.kv_store import KVClient, ShardedKVStore  # noqa: E402
from repro.core.dds_server import ServerConfig  # noqa: E402

JSON_PATH = os.path.join(os.path.dirname(__file__), "..",
                         "BENCH_writepath.json")

FULL_SPEEDUP_GATE = 2.0       # acceptance: overhaul >= 2x the pre-PR path
SMOKE_REGRESSION_GATE = 0.70  # CI: fail below 70% of recorded current
MODELED_DRIFT = 0.05          # modeled us/req must stay within 5%

CONFIGS = {
    "full": dict(shards=4, clients=2, warm_keys=96, rounds=12,
                 puts=64, gets=40, dels=8, value_size=96),
    "smoke": dict(shards=2, clients=1, warm_keys=48, rounds=5,
                  puts=32, gets=20, dels=4, value_size=96),
}


def calibrate(iters: int = 200_000) -> float:
    """Reference ops/sec of a fixed pure-Python loop (machine-speed proxy).

    Identical in spirit to ``fig_hotpath.calibrate``: struct packing, dict
    traffic and bytes slicing — the primitives the host path leans on.
    """
    pack = struct.Struct("<QII").pack
    blob = bytes(range(256)) * 8
    t0 = time.perf_counter()
    d: dict[int, bytes] = {}
    for i in range(iters):
        d[i & 1023] = blob[i & 255 : (i & 255) + 64]
        pack(i, i & 0xFFFF, 64)
    dt = time.perf_counter() - t0
    return iters / dt


def _drain(clients, cluster, rids: set) -> None:
    """Pump until every rid in ``rids`` has been answered (and popped)."""
    for _ in range(2_000_000):
        if not rids:
            return
        work = cluster.pump()
        for cli in clients:
            work += cli.net.poll()
        for cli in clients:
            resp = cli.net.responses
            done = rids & resp.keys()
            for rid in done:
                resp.pop(rid)
                cli.net._rid_shard.pop(rid, None)
            rids -= done
        if work == 0:
            for srv in cluster.servers:
                srv.device.drain()
    raise TimeoutError(f"{len(rids)} requests never answered")


def run_workload(cfg: dict) -> dict:
    """Drive the pipelined mixed workload; return measured + modeled rates."""
    store = ShardedKVStore(num_shards=cfg["shards"],
                           config=ServerConfig(device_capacity=1 << 26,
                                               cache_items=1 << 14))
    cluster = store.cluster
    clients = [KVClient(store) for _ in range(cfg["clients"])]
    value = bytes(range(256))[: cfg["value_size"]]

    # Warm set (untimed): PUT-acked keys whose GETs are guaranteed DPU-served.
    settled: list[list[bytes]] = [[] for _ in clients]
    warm_rids: set[int] = set()
    for ci, cli in enumerate(clients):
        for i in range(cfg["warm_keys"]):
            key = b"w%d-%d" % (ci, i)
            warm_rids.add(cli.put(key, value))
            settled[ci].append(key)
        cli.net.flush()
    _drain(clients, cluster, warm_rids)

    total = (cfg["rounds"] * cfg["clients"]
             * (cfg["puts"] + cfg["gets"] + cfg["dels"]))
    dpu_before = store.dpu_served_gets()
    host_before = store.host_served_gets()
    modeled_before = cluster.makespan_s()
    gc.collect()
    gc.disable()   # keep collector pauses out of the timed region
    t0 = time.perf_counter()
    # Pipeline depth 2: round r is issued while round r-1 is in flight;
    # round r-2 is fully acked, so its keys are settled for GET/DEL.
    pending: set[int] = set()     # rids of the PREVIOUS round
    unsettle: list[list[list[bytes]]] = [[[] for _ in clients]]
    for r in range(cfg["rounds"]):
        round_rids: set[int] = set()
        fresh = [[] for _ in clients]
        for ci, cli in enumerate(clients):
            pool = settled[ci]
            # write-heavy: every 4th PUT overwrites a settled key (cache
            # upsert), the rest append fresh keys
            for j in range(cfg["puts"]):
                if j % 4 == 3 and pool:
                    key = pool[j % len(pool)]
                else:
                    key = b"c%dr%dp%d" % (ci, r, j)
                    fresh[ci].append(key)
                round_rids.add(cli.put(key, value))
            for j in range(cfg["gets"]):
                round_rids.add(cli.get(pool[j % len(pool)]))
            for j in range(cfg["dels"]):
                # churn: delete from the oldest settled keys, never re-read
                round_rids.add(cli.delete(pool.pop(0)))
            cli.net.flush()
        unsettle.append(fresh)
        # Wait for round r-1 (keeps r in flight => depth-2 pipelining).
        while pending:
            work = cluster.pump()
            for cli in clients:
                work += cli.net.poll()
            for cli in clients:
                resp = cli.net.responses
                done = pending & resp.keys()
                for rid in done:
                    resp.pop(rid)
                    cli.net._rid_shard.pop(rid, None)
                pending -= done
            if work == 0:
                for srv in cluster.servers:
                    srv.device.drain()
        # Round r-1 acked: its fresh PUT keys are settled for round r+1.
        if len(unsettle) >= 2:
            for ci, keys in enumerate(unsettle[-2]):
                settled[ci].extend(keys)
        pending = round_rids
    _drain(clients, cluster, pending)
    elapsed = time.perf_counter() - t0
    gc.enable()

    dpu_gets = store.dpu_served_gets() - dpu_before
    host_gets = store.host_served_gets() - host_before
    copies = sum(s.file_service.stats.request_copies
                 for s in cluster.servers)
    assert copies == 0, f"zero-copy write invariant violated: {copies} copies"
    writes = sum(s.file_service.stats.writes for s in cluster.servers)
    assert writes > 0, "no host-path writes executed?"
    modeled_s = cluster.makespan_s() - modeled_before
    gets_total = cfg["rounds"] * cfg["clients"] * cfg["gets"]
    return {
        "requests": total,
        "wall_s": elapsed,
        "ops_per_s": total / elapsed,
        "modeled_us_per_req": modeled_s / total * 1e6,
        "dpu_get_frac": dpu_gets / max(gets_total, 1),
        "host_gets": host_gets,
        "fs_writes": writes,
    }


def load_json() -> dict:
    if os.path.exists(JSON_PATH):
        with open(JSON_PATH) as fh:
            return json.load(fh)
    return {"schema": 1, "configs": CONFIGS}


def save_json(doc: dict) -> None:
    with open(JSON_PATH, "w") as fh:
        json.dump(doc, fh, indent=2, sort_keys=True)
        fh.write("\n")


def main() -> None:
    argv = sys.argv[1:]
    smoke = ("--smoke" in argv
             or os.environ.get("DDS_BENCH_SMOKE", "0") == "1")
    record = ("baseline" if "--record-baseline" in argv else
              "current" if "--record-current" in argv else None)
    mode = "smoke" if smoke else "full"
    cfg = CONFIGS[mode]

    ops = cfg["puts"] + cfg["gets"] + cfg["dels"]
    section(f"write path ({mode}: {cfg['shards']} shards, {cfg['clients']} "
            f"clients, {cfg['rounds']}x{ops} mixed ops, "
            f"{cfg['puts']}P/{cfg['gets']}G/{cfg['dels']}D)")
    # Noise strategy: every workload rep is PAIRED with the calibration
    # measured right around it (max of before/after), and the rep with the
    # best *normalized* rate wins.  Pairing controls for machine-speed
    # drift WITHIN a run (CPU throttling mid-benchmark skews a
    # global-max-calibration scheme toward spurious failures); the
    # committed number remains an (ops, calibration) pair from one moment
    # in time, so cross-machine rescaling works exactly as in fig_hotpath.
    reps = 2 if smoke else 4
    calib, res = 0.0, None
    c_before = calibrate()
    for _ in range(reps):
        r = run_workload(cfg)
        c_after = calibrate()
        c = max(c_before, c_after)
        if res is None or r["ops_per_s"] / c > res["ops_per_s"] / calib:
            calib, res = c, r
        c_before = c_after
    emit(f"writepath_{mode}", 1e6 / res["ops_per_s"],
         f"tput={res['ops_per_s']:.0f}op/s "
         f"modeled={res['modeled_us_per_req']:.2f}us/req "
         f"dpu_gets={res['dpu_get_frac']:.2f}")

    doc = load_json()
    doc["configs"] = CONFIGS
    res = {**res, "config": cfg}   # pin the workload the numbers came from
    entry = {"calibration_ops_per_s": calib, mode: res}
    if record:
        doc.setdefault(record, {})["calibration_ops_per_s"] = calib
        doc[record][mode] = res
        print(f"# recorded {mode} measurement into '{record}'")
    doc["last_run"] = {"mode": mode, **entry}
    base, cur = doc.get("baseline", {}), doc.get("current", {})
    if base.get("full") and cur.get("full"):
        b = base["full"]["ops_per_s"] / base["calibration_ops_per_s"]
        c = cur["full"]["ops_per_s"] / cur["calibration_ops_per_s"]
        doc["speedup_full_calibrated"] = round(c / b, 3)
        doc["speedup_full_raw"] = round(cur["full"]["ops_per_s"]
                                        / base["full"]["ops_per_s"], 3)
    save_json(doc)

    def gate_ref(sec: dict, which: str):
        """Recorded numbers are only comparable on the SAME workload."""
        ref = sec.get(which)
        if ref and ref.get("config") != cfg:
            print(f"# recorded {which} numbers used a different workload "
                  f"config; gate skipped — re-record with the new config")
            return None
        return ref

    failures = []

    def check_modeled(ref: dict) -> None:
        """Modeled time is the physics; the overhaul must not move it."""
        b, c = ref["modeled_us_per_req"], res["modeled_us_per_req"]
        if abs(c - b) > MODELED_DRIFT * b:
            failures.append(
                f"modeled us/req drifted: {c:.3f} vs recorded {b:.3f}")

    if not smoke and not record:
        ref = gate_ref(doc.get("baseline", {}), "full")
        if ref:
            scale = calib / doc["baseline"]["calibration_ops_per_s"]
            target = ref["ops_per_s"] * scale * FULL_SPEEDUP_GATE
            ok = res["ops_per_s"] >= target
            print(f"# speedup vs baseline (calibrated): "
                  f"{res['ops_per_s'] / (ref['ops_per_s'] * scale):.2f}x "
                  f"(gate {FULL_SPEEDUP_GATE:.1f}x) -> {'OK' if ok else 'FAIL'}")
            if not ok:
                failures.append(
                    f"write path below {FULL_SPEEDUP_GATE}x baseline: "
                    f"{res['ops_per_s']:.0f} < {target:.0f} op/s")
            check_modeled(ref)
        else:
            print("# no recorded baseline; gate skipped")
    if smoke and not record:
        ref = gate_ref(doc.get("current", {}), "smoke")
        if ref:
            scale = calib / doc["current"]["calibration_ops_per_s"]
            target = ref["ops_per_s"] * scale * SMOKE_REGRESSION_GATE
            ok = res["ops_per_s"] >= target
            print(f"# smoke vs recorded current (calibrated): "
                  f"{res['ops_per_s'] / (ref['ops_per_s'] * scale):.2f}x "
                  f"(gate {SMOKE_REGRESSION_GATE:.2f}x) -> "
                  f"{'OK' if ok else 'FAIL'}")
            if not ok:
                failures.append(
                    f"write path regressed >30% vs recorded current: "
                    f"{res['ops_per_s']:.0f} < {target:.0f} op/s")
            check_modeled(ref)
        else:
            print("# no recorded current numbers; gate skipped")
    if failures:
        raise RuntimeError("; ".join(failures))


if __name__ == "__main__":
    main()
