"""Chaos gate: lossy wire, partitioned primary, dead DPU — zero loss, once.

PR 9 makes network faults first-class: a seeded :class:`FaultSchedule`
drops / duplicates / reorders / delays / bit-corrupts frames on every
shard's client-facing wires, frame checksums turn corruption into loss,
client tick-timeouts resend from replay notes, and the server-side
dedup/reply cache makes every resend exactly-once.  On top of that ride
two degradation paths: a partitioned primary is failed over after two
silent heartbeat windows and later REJOINS as a replica (no split-brain),
and a failed DPU transparently bounces its offloaded GETs to the host.

This benchmark drives the fig_failover-style Zipfian RMW workload through
all of it at once — seeded fault storm on every wire, one timed partition
of the hottest shard (healed mid-run), one DPU failure on another shard —
and gates, all in deterministic TICKS:

  * **zero lost acked writes** — every value the client saw ack is
    byte-compared on every read and in a final sweep;
  * **zero duplicate applies** — per-(key, round) single-writer PUTs mean
    any resend that re-ran would leave an identical record twice in some
    shard's append-only log; the union of live shards' own logs is
    scanned (the ledger oracle);
  * **bounded blip** — the partition round gets detection (two heartbeat
    windows) + slack on top of the steady p99; later rounds recover;
  * **injection disarmed is free** — the same workload with FaultWire
    wrappers installed but NO schedule must stay >= ``TPUT_GATE`` (0.9x)
    of the bare, unwrapped run's ops/tick;
  * **determinism** — two same-seed faulted runs produce identical round
    ticks, events, ledgers and injection counters.

Results go to ``BENCH_chaos.json``; ``--smoke`` (CI) runs a reduced
config and fails on a >30% tick regression vs the committed ``current``.
"""

from __future__ import annotations

import gc
import json
import os
import struct
import sys
import time
import zlib

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.common import emit, section  # noqa: E402
from repro.apps.kv_store import (KVClient, REC_HDR, ShardedKVStore,  # noqa: E402
                                 decode_record)
from repro.core import wire  # noqa: E402
from repro.core.dds_server import ServerConfig  # noqa: E402
from repro.core.faultnet import FaultSchedule, wrap_director  # noqa: E402

JSON_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_chaos.json")

TPUT_GATE = 0.9         # disarmed-wrapper ops/tick >= 0.9x bare ops/tick
BLIP_SLACK = 32         # partition-round allowance beyond detection + p99
RECOVERY_SLACK = 16     # post-heal round p99 allowance over steady p99
SMOKE_REGRESSION = 1.3  # CI: fail when blip/steady ticks grow >30%

CONFIGS = {
    "full": dict(shards=6, clients=2, hot_keys=48, zipf_a=2.5, rounds=24,
                 partition_round=8, partition_ticks=200, dpu_fail_round=16,
                 gets=96, overwrites=48, value_size=64, queue_depth=4,
                 heartbeat_timeout_ticks=6, timeout_ticks=96,
                 dedup_cache=4096,
                 drop=0.02, dup=0.02, reorder=0.01, delay=0.01,
                 corrupt=0.01),
    "smoke": dict(shards=4, clients=2, hot_keys=24, zipf_a=2.5, rounds=12,
                  partition_round=4, partition_ticks=140, dpu_fail_round=8,
                  gets=64, overwrites=32, value_size=64, queue_depth=4,
                  heartbeat_timeout_ticks=6, timeout_ticks=96,
                  dedup_cache=4096,
                  drop=0.02, dup=0.02, reorder=0.01, delay=0.01,
                  corrupt=0.01),
}

ZIPF_SEED = 0xFA110
FAULT_SEED = 0xC4A05


def calibrate(iters: int = 200_000) -> float:
    """Reference ops/sec of a fixed pure-Python loop (machine-speed proxy)."""
    pack = struct.Struct("<QII").pack
    blob = bytes(range(256)) * 8
    t0 = time.perf_counter()
    d: dict[int, bytes] = {}
    for i in range(iters):
        d[i & 1023] = blob[i & 255 : (i & 255) + 64]
        pack(i, i & 0xFFFF, 64)
    return iters / (time.perf_counter() - t0)


def percentile(vals: list[int], p: float) -> int:
    if not vals:
        return 0
    s = sorted(vals)
    return s[min(len(s) - 1, -(-len(s) * int(p) // 100) - 1)]


def _zipf_ranks(cfg: dict, total: int) -> list[int]:
    rng = np.random.default_rng(ZIPF_SEED)
    return [(int(z) - 1) % cfg["hot_keys"]
            for z in rng.zipf(cfg["zipf_a"], size=total)]


def _value(key: bytes, rnd: int, size: int) -> bytes:
    """Round-stamped value, a function of (key, round) only."""
    base = key + b"#%05d#" % rnd
    return (base * (size // len(base) + 1))[:size]


def _scan_own_logs(store) -> tuple[int, int]:
    """Ledger oracle: parse every live shard's OWN append-only record log.

    Returns ``(records, duplicate_applies)`` where a duplicate apply is an
    identical ``(key, value)`` record seen twice across the union — with
    per-(key, round) single-writer PUTs and round-stamped values, only a
    re-executed resend can produce one."""
    cl = store.cluster
    counts: dict[tuple[bytes, bytes], int] = {}
    records = 0
    for s, st in enumerate(store._states):
        if s in cl._dead:
            continue
        if not st.log_off:
            continue
        data = cl.servers[s].frontend.read_sync(st.log_fid, 0, st.log_off)
        pos = 0
        while pos + REC_HDR.size <= len(data):
            klen, vlen = REC_HDR.unpack_from(data, pos)
            total = REC_HDR.size + klen + vlen
            if pos + total > len(data):
                break
            key = bytes(data[pos + REC_HDR.size:pos + REC_HDR.size + klen])
            val = bytes(data[pos + REC_HDR.size + klen:pos + total])
            counts[(key, val)] = counts.get((key, val), 0) + 1
            records += 1
            pos += total
    dups = sum(c - 1 for c in counts.values() if c > 1)
    return records, dups


def run_chaos_workload(cfg: dict, *, faults: bool, wrappers: bool) -> dict:
    """Drive the settle-per-round Zipfian RMW loop.

    ``wrappers`` installs FaultWire on every shard's wires; ``faults``
    additionally arms the seeded schedules, partitions the hottest shard
    mid-run (healing it later) and fails one DPU."""
    config = ServerConfig(device_capacity=1 << 26, cache_items=1 << 14,
                          replication=1, wire_checksums=True,
                          dedup_cache=cfg["dedup_cache"],
                          heartbeat_timeout_ticks=cfg[
                              "heartbeat_timeout_ticks"])
    store = ShardedKVStore(num_shards=cfg["shards"], config=config)
    cluster = store.cluster
    for srv in cluster.servers:
        srv.device.queue_depth = cfg["queue_depth"]
    wires = []
    if wrappers:
        for s, srv in enumerate(cluster.servers):
            sched_in = sched_out = None
            if faults:
                sched_in = FaultSchedule(
                    seed=FAULT_SEED ^ s, drop=cfg["drop"], dup=cfg["dup"],
                    reorder=cfg["reorder"], delay=cfg["delay"],
                    delay_ticks=(1, 3), corrupt=cfg["corrupt"])
                sched_out = FaultSchedule(
                    seed=FAULT_SEED ^ s ^ 0x9E3779B9, drop=cfg["drop"],
                    dup=cfg["dup"], reorder=cfg["reorder"],
                    delay=cfg["delay"], delay_ticks=(1, 3),
                    corrupt=cfg["corrupt"])
            # Lossy CLIENT network over a reliable backend fabric: the
            # inter-shard replication flows (port 45000+ on either end —
            # forwards ride the target's ingress, acks ride its response
            # wire) have no retransmit layer of their own — a lost
            # forward or ack would wedge a held ack forever, which is a
            # transport the paper models as reliable (RDMA RC), not a
            # storage bug.
            wires.extend(wrap_director(
                srv.director, cluster.clock,
                ingress=sched_in, responses=sched_out,
                flow_filter=lambda f: (f.src_port < 45000
                                       and f.dst_port < 45000)))
    clients = [KVClient(store, timeout_ticks=cfg["timeout_ticks"])
               for _ in range(cfg["clients"])]
    vsize = cfg["value_size"]
    nclients = cfg["clients"]
    hot = [b"hot-%04d" % i for i in range(cfg["hot_keys"])]

    # Untimed warm: PUT-ack every hot key through client 0.
    acked: dict[bytes, bytes] = {}
    rids = clients[0].submit([("put", k, _value(k, -1, vsize)) for k in hot])
    res = clients[0].harvest(rids)
    assert all(s == wire.E_OK for s, _ in res.values())
    for k in hot:
        acked[k] = _value(k, -1, vsize)
    res = clients[0].harvest(clients[0].submit([("get", k) for k in hot]))
    assert all(s == wire.E_OK for s, _ in res.values())
    for cli in clients:
        cli.net.run_until_idle()

    per_round = cfg["gets"] + cfg["overwrites"]
    ranks = _zipf_ranks(cfg, cfg["rounds"] * nclients * per_round)
    rk = iter(ranks)
    round_ticks: list[int] = []
    lost = 0
    total = 0
    victim = dpu_victim = None
    gc.collect()
    gc.disable()
    t0 = time.perf_counter()
    for r in range(cfg["rounds"]):
        if faults and r == cfg["partition_round"]:
            # Partition the shard owning the hottest key, two ticks into
            # the round; its network heals partition_ticks later — well
            # after the supervisor has promoted its replica.
            victim = store.shard_for_key(hot[0])
            cluster.partition(victim,
                              cluster.clock.now + cfg["partition_ticks"])
        if faults and r == cfg["dpu_fail_round"]:
            # Fail a DIFFERENT live shard's DPU: its offloaded GETs must
            # degrade to the host path without a correctness ripple.
            for k in hot[1:]:
                s = store.shard_for_key(k)
                if s != victim and s not in cluster._dead:
                    dpu_victim = s
                    cluster.servers[s].offload.fail()
                    break
        t_start = cluster.clock.now
        # Read phase: byte-compare every GET against the acked ledger.
        gmeta = []
        for cli in clients:
            ks = [hot[next(rk)] for _ in range(cfg["gets"])]
            gmeta.append((cli, ks, cli.submit([("get", k) for k in ks])))
        for cli, ks, rg in gmeta:
            res = cli.harvest(rg)
            for k, rid in zip(ks, rg):
                status, body = res[rid]
                if status != wire.E_OK or decode_record(body)[1] != acked[k]:
                    lost += 1
        # Modify phase: per-(key, round) single-writer overwrites — the
        # duplicate-apply oracle needs every (key, value) record to have
        # exactly one legitimate producer.  Keys drawn by all clients are
        # deduped, then each is assigned a deterministic designated
        # writer for this round.
        drawn = [hot[next(rk)]
                 for _ in range(nclients * cfg["overwrites"])]
        uniq = list(dict.fromkeys(drawn))
        per_client: list[list[bytes]] = [[] for _ in range(nclients)]
        for k in uniq:
            per_client[(zlib.crc32(k) + r) % nclients].append(k)
        pmeta = []
        for cli, ks in zip(clients, per_client):
            pmeta.append((cli, ks, cli.submit(
                [("put", k, _value(k, r, vsize)) for k in ks])))
        for cli, ks, rp in pmeta:
            res = cli.harvest(rp)
            for k, rid in zip(ks, rp):
                if res[rid][0] == wire.E_OK:
                    acked[k] = _value(k, r, vsize)
                else:
                    lost += 1
        for cli in clients:
            cli.net.run_until_idle()
        total += nclients * cfg["gets"] + len(uniq)
        round_ticks.append(cluster.clock.now - t_start)
    # Let the heal land if the rounds outran partition_ticks, then sweep
    # the whole ledger.
    if faults and victim is not None:
        guard = 0
        while not cluster.rejoin_events and guard < 10_000:
            cluster.pump()
            guard += 1
    sweep = clients[0].submit([("get", k) for k in hot])
    res = clients[0].harvest(sweep)
    for k, rid in zip(hot, sweep):
        status, body = res[rid]
        if status != wire.E_OK or decode_record(body)[1] != acked[k]:
            lost += 1
    for cli in clients:
        cli.net.run_until_idle()
    cluster.run_until_idle()
    elapsed = time.perf_counter() - t0
    gc.enable()

    pr = cfg["partition_round"]
    steady = round_ticks[:pr]
    # Recovery window: past the partition round AND the promote/heal
    # rounds that follow it (re-silver + catch-up are legitimate one-off
    # costs, not a failure to recover).
    post = round_ticks[pr + 3:]
    records, dup_applies = _scan_own_logs(store)
    stats = cluster.latency_stats()
    injection = {"dropped": 0, "duplicated": 0, "reordered": 0, "delayed": 0,
                 "corrupted": 0, "partition_dropped": 0}
    for fw in wires:
        for k, v in fw.totals.items():
            injection[k] += v
    out = {
        "requests": total,
        "ticks": cluster.clock.now,
        "wall_s": elapsed,
        "ops_per_s": total / elapsed,
        "lost_acked": lost,
        "dup_applies": dup_applies,
        "log_records": records,
        "round_ticks": round_ticks,
        "steady_ops_per_tick": total / max(sum(round_ticks), 1),
        "steady_p99": percentile(steady, 99),
        "steady_median": percentile(steady, 50),
        "blip_ticks": round_ticks[pr] if faults else 0,
        "post_p99": percentile(post, 99) if faults else 0,
        "post_median": percentile(post, 50) if faults else 0,
        "injection": injection,
        "client": {
            "timeouts": sum(c.net.stats.timeouts for c in clients),
            "resends": sum(c.net.stats.resends for c in clients),
            "dup_responses": sum(c.net.stats.dup_responses
                                 for c in clients),
        },
        "wire": stats.get("wire", {}),
        "exactly_once": stats.get("exactly_once", {}),
    }
    if faults:
        out["failover"] = {"victim": victim,
                           "events": list(cluster.failover_events)}
        out["rejoins"] = list(cluster.rejoin_events)
        out["dpu_victim"] = dpu_victim
    return out


def load_json() -> dict:
    if os.path.exists(JSON_PATH):
        with open(JSON_PATH) as fh:
            return json.load(fh)
    return {"schema": 1, "configs": CONFIGS}


def save_json(doc: dict) -> None:
    with open(JSON_PATH, "w") as fh:
        json.dump(doc, fh, indent=2, sort_keys=True)
        fh.write("\n")


def main() -> None:
    argv = sys.argv[1:]
    smoke = ("--smoke" in argv
             or os.environ.get("DDS_BENCH_SMOKE", "0") == "1")
    record = ("current" if "--record-current" in argv else None)
    mode = "smoke" if smoke else "full"
    cfg = CONFIGS[mode]

    section(f"chaos ({mode}: {cfg['shards']} shards K=1, "
            f"{cfg['clients']} clients, drop/dup {cfg['drop']:.0%}, "
            f"partition at round {cfg['partition_round']}, DPU fail at "
            f"round {cfg['dpu_fail_round']}, {cfg['rounds']} rounds)")
    c1 = calibrate()
    res = run_chaos_workload(cfg, faults=True, wrappers=True)
    rep2 = run_chaos_workload(cfg, faults=True, wrappers=True)
    disarmed = run_chaos_workload(cfg, faults=False, wrappers=True)
    bare = run_chaos_workload(cfg, faults=False, wrappers=False)
    c2 = calibrate()
    calib = max(c1, c2)
    identical = all(res[k] == rep2[k] for k in
                    ("round_ticks", "failover", "rejoins", "lost_acked",
                     "dup_applies", "log_records", "ticks", "requests",
                     "injection", "client", "wire", "exactly_once"))
    tput_ratio = (disarmed["steady_ops_per_tick"]
                  / max(bare["steady_ops_per_tick"], 1e-9))
    inj = sum(res["injection"].values())
    emit(f"chaos_{mode}", float(res["blip_ticks"]),
         f"lost_acked={res['lost_acked']} dup_applies={res['dup_applies']} "
         f"injected={inj} resends={res['client']['resends']} "
         f"blip={res['blip_ticks']}t steady_p99={res['steady_p99']}t "
         f"disarmed_ratio={tput_ratio:.2f}x deterministic={identical} "
         f"tput={res['ops_per_s']:.0f}op/s")
    emit(f"chaos_{mode}_exactly_once",
         float(res["exactly_once"].get("replayed_acks", 0)),
         f"dup_suppressed={res['exactly_once'].get('dup_suppressed', 0)} "
         f"replayed_acks={res['exactly_once'].get('replayed_acks', 0)} "
         f"corrupt_dropped={res['wire'].get('corrupt_dropped', 0)} "
         f"dpu_bypassed={res['wire'].get('dpu_bypassed', 0)}")

    doc = load_json()
    doc["configs"] = CONFIGS
    res_out = {k: v for k, v in res.items() if k != "round_ticks"}
    res_out["config"] = cfg
    res_out["deterministic"] = identical
    res_out["disarmed_tput_ratio_vs_bare"] = round(tput_ratio, 3)
    res_out["bare_steady_ops_per_tick"] = round(
        bare["steady_ops_per_tick"], 3)
    entry = {"calibration_ops_per_s": calib, mode: res_out}
    if record:
        doc.setdefault("current", {})["calibration_ops_per_s"] = calib
        doc["current"][mode] = res_out
        print(f"# recorded {mode} measurement into 'current'")
    doc["last_run"] = {"mode": mode, **entry}
    save_json(doc)

    failures = []
    if res["lost_acked"]:
        failures.append(f"{res['lost_acked']} acknowledged writes lost or "
                        f"stale under chaos (gate: zero)")
    if res["dup_applies"]:
        failures.append(f"{res['dup_applies']} duplicate applies in the "
                        f"record logs (gate: zero — a resend re-ran)")
    if not identical:
        failures.append("two same-seed chaos runs diverged — "
                        "determinism gate")
    if not res["failover"]["events"]:
        failures.append("partition never promoted a replica")
    if not res["rejoins"]:
        failures.append("partitioned shard never rejoined after heal")
    if not res["wire"].get("dpu_bypassed"):
        failures.append("DPU failure never bounced a GET to the host")
    if not res["wire"].get("corrupt_dropped"):
        failures.append("no corrupt frame was ever checksum-dropped "
                        "(injection not reaching the wire?)")
    detect = 2 * (cfg["heartbeat_timeout_ticks"] + 1)   # miss_windows = 2
    blip_limit = res["steady_p99"] + detect + BLIP_SLACK
    ok = res["blip_ticks"] <= blip_limit
    print(f"# partition-round blip: {res['blip_ticks']}t (steady p99 "
          f"{res['steady_p99']}t + detection {detect}t + slack "
          f"{BLIP_SLACK}t = limit {blip_limit}t) -> "
          f"{'OK' if ok else 'FAIL'}")
    if not ok:
        failures.append(f"partition blip unbounded: {res['blip_ticks']} > "
                        f"{blip_limit} ticks")
    # Median, not p99: individual post rounds are heavy-tailed by design
    # (a dropped batch frame costs a timeout chain), so the recovery
    # question is whether the TYPICAL round returns to steady shape.
    rec_limit = res["steady_median"] + RECOVERY_SLACK
    ok = res["post_median"] <= rec_limit
    print(f"# post-chaos round median: {res['post_median']}t (steady "
          f"median {res['steady_median']}t + slack {RECOVERY_SLACK}t = "
          f"limit {rec_limit}t; post p99 {res['post_p99']}t) -> "
          f"{'OK' if ok else 'FAIL'}")
    if not ok:
        failures.append(f"post-chaos median never recovered: "
                        f"{res['post_median']} > {rec_limit} ticks")
    ok = tput_ratio >= TPUT_GATE
    print(f"# ops/tick, disarmed wrappers vs bare (deterministic): "
          f"{disarmed['steady_ops_per_tick']:.2f} vs "
          f"{bare['steady_ops_per_tick']:.2f} ({tput_ratio:.2f}x; gate "
          f"{TPUT_GATE:.2f}x) -> {'OK' if ok else 'FAIL'}")
    if not ok:
        failures.append(f"disarmed FaultWire too expensive: "
                        f"{tput_ratio:.2f}x < {TPUT_GATE:.2f}x bare")
    if smoke and not record:
        ref = doc.get("current", {}).get("smoke")
        if ref and ref.get("config") == cfg:
            for key in ("blip_ticks", "steady_p99"):
                limit = max(ref[key], 1) * SMOKE_REGRESSION
                if res[key] > limit:
                    failures.append(
                        f"{key} regressed >30% vs recorded current: "
                        f"{res[key]} > {limit:.1f} ticks")
            print(f"# smoke vs recorded current: blip {res['blip_ticks']}t "
                  f"vs {ref['blip_ticks']}t, steady p99 {res['steady_p99']}t "
                  f"vs {ref['steady_p99']}t")
        else:
            print("# no comparable recorded current numbers; "
                  "smoke regression gate skipped")
    if failures:
        raise RuntimeError("; ".join(failures))


if __name__ == "__main__":
    main()
