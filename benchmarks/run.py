"""Benchmark entry point: one module per paper table/figure.

``python -m benchmarks.run``            everything (measured + model + roofline)
``python -m benchmarks.run fig17``      one module
``python -m benchmarks.run --smoke``    CI nightly gate (modules that
                                        support it run reduced sizes)

Output rows: ``name,us_per_call,derived``.
"""

from __future__ import annotations

import os
import sys
import traceback

# Allow direct invocation (`python benchmarks/run.py`) in addition to
# `python -m benchmarks.run`: put the repo root and src/ on the path.
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (_ROOT, os.path.join(_ROOT, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

from benchmarks import (compare, fig14_16_model, fig17_rings,
                        fig18_23_zerocopy, fig22_cache_table,
                        fig24_26_integration, fig_chaos,
                        fig_cluster_scaling, fig_failover, fig_getstorm,
                        fig_hotpath, fig_latency, fig_reshard,
                        fig_scaleout, fig_tenancy, fig_writepath,
                        kernels_bench, roofline)

MODULES = {
    "cluster": fig_cluster_scaling,
    "hotpath": fig_hotpath,
    "writepath": fig_writepath,
    "scaleout": fig_scaleout,
    "latency": fig_latency,
    "tenancy": fig_tenancy,
    "failover": fig_failover,
    "getstorm": fig_getstorm,
    "chaos": fig_chaos,
    "reshard": fig_reshard,
    "fig14_16": fig14_16_model,
    "fig17": fig17_rings,
    "fig18_23": fig18_23_zerocopy,
    "fig22": fig22_cache_table,
    "fig24_26": fig24_26_integration,
    "kernels": kernels_bench,
    "roofline": roofline,
    "compare": compare,
}


def main() -> None:
    args = sys.argv[1:]
    if "--smoke" in args:
        # Size reduction is opt-in per module: modules that support it (so
        # far: cluster) read DDS_BENCH_SMOKE; the rest run at full size.
        os.environ["DDS_BENCH_SMOKE"] = "1"
        args = [a for a in args if a != "--smoke"]
    wanted = args or list(MODULES)
    failures = 0
    for name in wanted:
        mod = MODULES.get(name)
        if mod is None:
            print(f"# unknown benchmark {name}; choices: {list(MODULES)}")
            failures += 1
            continue
        try:
            mod.main()
        except Exception:
            failures += 1
            print(f"# BENCHMARK {name} FAILED")
            traceback.print_exc()
    if failures:
        raise SystemExit(failures)


if __name__ == "__main__":
    main()
