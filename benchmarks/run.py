"""Benchmark entry point: one module per paper table/figure.

``python -m benchmarks.run``            everything (measured + model + roofline)
``python -m benchmarks.run fig17``      one module

Output rows: ``name,us_per_call,derived``.
"""

from __future__ import annotations

import sys
import traceback

from benchmarks import (compare, fig14_16_model, fig17_rings,
                        fig18_23_zerocopy, fig22_cache_table,
                        fig24_26_integration, kernels_bench, roofline)

MODULES = {
    "fig14_16": fig14_16_model,
    "fig17": fig17_rings,
    "fig18_23": fig18_23_zerocopy,
    "fig22": fig22_cache_table,
    "fig24_26": fig24_26_integration,
    "kernels": kernels_bench,
    "roofline": roofline,
    "compare": compare,
}


def main() -> None:
    wanted = sys.argv[1:] or list(MODULES)
    failures = 0
    for name in wanted:
        mod = MODULES.get(name)
        if mod is None:
            print(f"# unknown benchmark {name}; choices: {list(MODULES)}")
            failures += 1
            continue
        try:
            mod.main()
        except Exception:
            failures += 1
            print(f"# BENCHMARK {name} FAILED")
            traceback.print_exc()
    if failures:
        raise SystemExit(failures)


if __name__ == "__main__":
    main()
