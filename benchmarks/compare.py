"""Baseline vs optimized comparisons (same-basis).

Two sections:

  * the paper-faithful-baseline sweep (results/dryrun) vs the optimized
    sweep (results/dryrun_opt): per-cell dominant-term change.  Both sweeps
    are full-config lowerings (scan bodies counted once in both), so ratios
    are exact even though absolute terms need extrapolation;
  * the committed measured-latency record (BENCH_latency.json): pre-overhaul
    baseline vs current per-class completion-tick percentiles — ticks are
    machine-independent, so the comparison needs no calibration.
"""

from __future__ import annotations

import glob
import json
import os

from benchmarks.common import emit, section

BASE = "results/dryrun"
OPT = "results/dryrun_opt"
LATENCY_JSON = os.path.join(os.path.dirname(__file__), "..",
                            "BENCH_latency.json")
TENANCY_JSON = os.path.join(os.path.dirname(__file__), "..",
                            "BENCH_tenancy.json")
FAILOVER_JSON = os.path.join(os.path.dirname(__file__), "..",
                             "BENCH_failover.json")
GETSTORM_JSON = os.path.join(os.path.dirname(__file__), "..",
                             "BENCH_getstorm.json")
CHAOS_JSON = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_chaos.json")
RESHARD_JSON = os.path.join(os.path.dirname(__file__), "..",
                            "BENCH_reshard.json")


def _load(d: str) -> dict:
    out = {}
    for p in glob.glob(os.path.join(d, "*.json")):
        name = os.path.basename(p)[:-5]
        if "__L" in name:
            continue
        with open(p) as f:
            out[name] = json.load(f)
    return out


def latency_compare() -> None:
    """Committed tail-latency ticks: pre-overhaul baseline vs current."""
    if not os.path.exists(LATENCY_JSON):
        print("# no BENCH_latency.json; latency comparison skipped")
        return
    with open(LATENCY_JSON) as fh:
        doc = json.load(fh)
    base = doc.get("baseline", {}).get("full")
    cur = doc.get("current", {}).get("full")
    if not base or not cur:
        print("# BENCH_latency.json lacks baseline/current; skipped")
        return
    section("measured tail latency (ticks): pre-overhaul -> current")
    for cls in ("get", "write"):
        b, c = base[cls], cur[cls]
        for p in ("p50", "p95", "p99", "max"):
            if c[p]:
                rel = f"({b[p] / c[p]:.2f}x lower)"
            elif b[p]:
                rel = f"(sub-tick; was {b[p]}t)"   # no finite ratio to print
            else:
                rel = "(both sub-tick)"
            emit(f"latency_{cls}_{p}", float(c[p]),
                 f"{b[p]}t -> {c[p]}t {rel}")


def tenancy_compare() -> None:
    """Committed tenancy record: what QoS buys the victim, and its cost."""
    if not os.path.exists(TENANCY_JSON):
        print("# no BENCH_tenancy.json; tenancy comparison skipped")
        return
    with open(TENANCY_JSON) as fh:
        doc = json.load(fh)
    cur = doc.get("current", {}).get("full")
    if not cur:
        print("# BENCH_tenancy.json lacks current/full; skipped")
        return
    section("multi-tenant isolation (ticks): untenanted -> qos, same flood")
    solo = max(cur["solo"]["victim_get"]["p99"], 1)
    for p in ("p50", "p95", "p99", "max"):
        noisy = cur["untenanted"]["victim_get"][p]
        qos = cur["qos"]["victim_get"][p]
        emit(f"tenancy_victim_{p}", float(qos),
             f"{noisy}t -> {qos}t (solo {cur['solo']['victim_get'][p]}t)")
    tput = (cur["qos"]["served_per_tick"]
            / max(cur["untenanted"]["served_per_tick"], 1e-9))
    emit("tenancy_tput_ratio", cur["qos"]["served_per_tick"],
         f"qos {cur['qos']['served_per_tick']}/t vs untenanted "
         f"{cur['untenanted']['served_per_tick']}/t ({tput:.2f}x), "
         f"hog sheds {cur['qos']['hog_sheds']}, solo p99 {solo}t")


def failover_compare() -> None:
    """Committed failover record: what the kill-a-shard run cost, in ticks."""
    if not os.path.exists(FAILOVER_JSON):
        print("# no BENCH_failover.json; failover comparison skipped")
        return
    with open(FAILOVER_JSON) as fh:
        doc = json.load(fh)
    cur = doc.get("current", {}).get("full")
    if not cur:
        print("# BENCH_failover.json lacks current/full; skipped")
        return
    section("kill-a-shard failover (ticks): steady state -> crash round")
    emit("failover_blip", float(cur["blip_ticks"]),
         f"steady p99 {cur['steady_p99']}t -> crash round "
         f"{cur['blip_ticks']}t -> recovered p99 {cur['post_p99']}t, "
         f"lost_acked={cur['lost_acked']}")
    emit("failover_repl_cost", cur["tput_ratio_vs_unreplicated"],
         f"replicated steady at "
         f"{cur['tput_ratio_vs_unreplicated']:.2f}x the unreplicated "
         f"ops/tick ({cur['unreplicated_steady_ops_per_tick']}/t), "
         f"deterministic={cur.get('deterministic')}")


def getstorm_compare() -> None:
    """Committed GET-storm record: scalar data plane vs vectorized."""
    if not os.path.exists(GETSTORM_JSON):
        print("# no BENCH_getstorm.json; getstorm comparison skipped")
        return
    with open(GETSTORM_JSON) as fh:
        doc = json.load(fh)
    base = doc.get("baseline", {})
    cur = doc.get("current", {})
    bf, cf = base.get("full"), cur.get("full")
    if not bf or not cf:
        print("# BENCH_getstorm.json lacks baseline/current full; skipped")
        return
    section("vectorized data plane: scalar baseline -> array-at-a-time")
    # Calibrate both sides to this machine so the ratio survives host drift.
    b_cal = base.get("calibration_ops_per_s") or 1.0
    c_cal = cur.get("calibration_ops_per_s") or 1.0
    speedup = (cf["ops_per_s"] / c_cal) / (bf["ops_per_s"] / b_cal)
    emit("getstorm_full", cf["ops_per_s"],
         f"{bf['ops_per_s']:.0f} -> {cf['ops_per_s']:.0f} op/s "
         f"({speedup:.2f}x calibrated, "
         f"{cf['ops_per_s'] / bf['ops_per_s']:.2f}x raw), "
         f"ticks {bf['ticks']} -> {cf['ticks']}, "
         f"dpu_frac {cf['dpu_frac']:.2f}")


def chaos_compare() -> None:
    """Committed chaos record: what the fault storm cost, in ticks."""
    if not os.path.exists(CHAOS_JSON):
        print("# no BENCH_chaos.json; chaos comparison skipped")
        return
    with open(CHAOS_JSON) as fh:
        doc = json.load(fh)
    cur = doc.get("current", {}).get("full")
    if not cur:
        print("# BENCH_chaos.json lacks current/full; skipped")
        return
    section("lossy-network chaos (ticks): fault storm + partition + "
            "dead DPU")
    inj = cur.get("injection", {})
    emit("chaos_blip", float(cur["blip_ticks"]),
         f"steady median {cur['steady_median']}t -> partition round "
         f"{cur['blip_ticks']}t -> recovered median "
         f"{cur['post_median']}t, lost_acked={cur['lost_acked']}, "
         f"dup_applies={cur['dup_applies']}")
    emit("chaos_injection", float(sum(inj.values())),
         f"dropped={inj.get('dropped', 0)} dup={inj.get('duplicated', 0)} "
         f"reorder={inj.get('reordered', 0)} delay={inj.get('delayed', 0)} "
         f"corrupt={inj.get('corrupted', 0)}; "
         f"resends={cur.get('client', {}).get('resends', 0)}, "
         f"replayed_acks="
         f"{cur.get('exactly_once', {}).get('replayed_acks', 0)}")
    emit("chaos_disarmed_cost", cur["disarmed_tput_ratio_vs_bare"],
         f"disarmed wrappers at "
         f"{cur['disarmed_tput_ratio_vs_bare']:.2f}x the bare ops/tick "
         f"({cur['bare_steady_ops_per_tick']}/t), "
         f"deterministic={cur.get('deterministic')}")


def reshard_compare() -> None:
    """Committed resharding record: what mid-run growth bought, in ticks."""
    if not os.path.exists(RESHARD_JSON):
        print("# no BENCH_reshard.json; reshard comparison skipped")
        return
    with open(RESHARD_JSON) as fh:
        doc = json.load(fh)
    cur = doc.get("current", {}).get("full")
    if not cur:
        print("# BENCH_reshard.json lacks current/full; skipped")
        return
    cfg = cur.get("config", {})
    section("elastic resharding (ticks): "
            f"{cfg.get('shards')} -> {cfg.get('grow_to')} shards mid-run")
    emit("reshard_growth", cur["growth_ratio"],
         f"steady ops/tick {cur['pre_ops_per_tick']:.1f} -> "
         f"{cur['post_ops_per_tick']:.1f} ({cur['growth_ratio']:.2f}x), "
         f"lost_acked={cur['lost_acked']}, "
         f"deterministic={cur.get('deterministic')}")
    emit("reshard_blip", float(cur["grow_p99"]),
         f"round p99 pre {cur['pre_p99']}t -> during growth "
         f"{cur['grow_p99']}t -> post {cur['post_p99']}t; "
         f"migrated={cur['keys_migrated']} keys, "
         f"dual_routed={cur['dual_routed']}")
    emit("reshard_window", float(cur["grow_ticks_max"]),
         f"slowest joiner: add->flip {cur['flip_ticks_max']}t, "
         f"add->retired {cur['grow_ticks_max']}t")


def main() -> None:
    latency_compare()
    tenancy_compare()
    failover_compare()
    getstorm_compare()
    chaos_compare()
    reshard_compare()
    if not (os.path.isdir(BASE) and os.path.isdir(OPT)):
        print("# need both results/dryrun and results/dryrun_opt")
        return
    base, opt = _load(BASE), _load(OPT)
    section("baseline vs optimized: max roofline term per cell (single pod)")
    gains = []
    for name in sorted(base):
        if not name.endswith("__single"):
            continue
        b, o = base.get(name), opt.get(name)
        if not b or not o or b.get("status") != "ok" or o.get("status") != "ok":
            continue
        bt = max(b["compute_s"], b["memory_s"], b["collective_s"])
        ot = max(o["compute_s"], o["memory_s"], o["collective_s"])
        btemp = b["memory_analysis"]["temp_size_bytes"] / 2 ** 30
        otemp = o["memory_analysis"]["temp_size_bytes"] / 2 ** 30
        gains.append(bt / ot)
        emit(f"compare_{name[:-8]}", ot * 1e6,
             f"max_term {bt:.3g}s -> {ot:.3g}s ({bt / ot:.2f}x) "
             f"temp {btemp:.1f} -> {otemp:.1f} GiB "
             f"dominant {b['dominant']} -> {o['dominant']}")
    if gains:
        gm = 1.0
        for g in gains:
            gm *= g
        gm **= 1.0 / len(gains)
        emit("compare_geomean_gain", 0.0,
             f"{gm:.2f}x across {len(gains)} cells")


if __name__ == "__main__":
    main()
