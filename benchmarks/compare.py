"""Baseline vs optimized sweep comparison (all cells, same-basis).

Reads the paper-faithful-baseline sweep (results/dryrun) and the optimized
sweep (results/dryrun_opt) and prints the per-cell dominant-term change.
Both sweeps are full-config lowerings (scan bodies counted once in both),
so ratios are exact even though absolute terms need extrapolation.
"""

from __future__ import annotations

import glob
import json
import os

from benchmarks.common import emit, section

BASE = "results/dryrun"
OPT = "results/dryrun_opt"


def _load(d: str) -> dict:
    out = {}
    for p in glob.glob(os.path.join(d, "*.json")):
        name = os.path.basename(p)[:-5]
        if "__L" in name:
            continue
        with open(p) as f:
            out[name] = json.load(f)
    return out


def main() -> None:
    if not (os.path.isdir(BASE) and os.path.isdir(OPT)):
        print("# need both results/dryrun and results/dryrun_opt")
        return
    base, opt = _load(BASE), _load(OPT)
    section("baseline vs optimized: max roofline term per cell (single pod)")
    gains = []
    for name in sorted(base):
        if not name.endswith("__single"):
            continue
        b, o = base.get(name), opt.get(name)
        if not b or not o or b.get("status") != "ok" or o.get("status") != "ok":
            continue
        bt = max(b["compute_s"], b["memory_s"], b["collective_s"])
        ot = max(o["compute_s"], o["memory_s"], o["collective_s"])
        btemp = b["memory_analysis"]["temp_size_bytes"] / 2 ** 30
        otemp = o["memory_analysis"]["temp_size_bytes"] / 2 ** 30
        gains.append(bt / ot)
        emit(f"compare_{name[:-8]}", ot * 1e6,
             f"max_term {bt:.3g}s -> {ot:.3g}s ({bt / ot:.2f}x) "
             f"temp {btemp:.1f} -> {otemp:.1f} GiB "
             f"dominant {b['dominant']} -> {o['dominant']}")
    if gains:
        gm = 1.0
        for g in gains:
            gm *= g
        gm **= 1.0 / len(gains)
        emit("compare_geomean_gain", 0.0,
             f"{gm:.2f}x across {len(gains)} cells")


if __name__ == "__main__":
    main()
