"""Figs 18 & 23: zero-copy on the storage path and the offload engine.

MEASURED: the same request streams run with ``zero_copy`` on and off.

Fig 18 — host-issued file I/O through the rings + DPU file service, by
request size; the paper reports up to +93% throughput from eliminating the
request/response copies (§4.3).

Fig 23 — offloaded reads through the full server (traffic director ->
offload engine -> SSD): throughput and copies with and without the
pre-allocated read/packet buffers of §6.2 (paper: 520K -> 730K IOPS,
250 us -> 170 us).
"""

from __future__ import annotations

import time

from benchmarks.common import emit, section
from repro.core.dds_server import DDSClient, DDSStorageServer, ServerConfig
from repro.core.file_service import FileServiceRunner, SegmentFS
from repro.core.host_lib import DDSFrontEnd
from repro.core.ring import DMAEngine
from repro.storage.blockdev import BlockDevice

N_OPS = 400


def _file_io_rate(zero_copy: bool, size: int) -> tuple[float, int]:
    dev = BlockDevice(1 << 24, block_size=512)
    fs = SegmentFS(dev, 1 << 16)
    svc = FileServiceRunner(fs, DMAEngine(), zero_copy=zero_copy)
    fe = DDSFrontEnd(svc, ring_capacity=1 << 18)
    fid = fe.create_file("bench")
    fe.write_sync(fid, 0, bytes(size))
    gid = fe._control_group
    t0 = time.perf_counter()
    done = issued = 0
    # Pipelined: drain responses while keeping a bounded window in flight
    # (an un-drained host would otherwise trip the service's load shedding).
    window = max(2, (1 << 17) // (size + 64))
    inflight = 0
    while done < N_OPS:
        while inflight < window and issued < N_OPS:
            fe.read_file(fid, 0, size)
            issued += 1
            inflight += 1
        svc.step()
        got = len(fe.poll_wait(gid))
        done += got
        inflight -= got
    dt = time.perf_counter() - t0
    return N_OPS / dt, svc.stats.response_copies + svc.stats.request_copies


def _offload_rate(zero_copy: bool, size: int) -> tuple[float, int]:
    srv = DDSStorageServer(ServerConfig(zero_copy=zero_copy))
    fid = srv.frontend.create_file("data")
    srv.frontend.write_sync(fid, 0, bytes(max(size * 4, 4096)))
    srv.run_until_idle()
    cli = DDSClient(srv)
    t0 = time.perf_counter()
    for i in range(N_OPS):
        rid = cli.read(fid, 0, size)
        if i % 16 == 15:
            cli.wait(rid)
    # drain the rest
    for _ in range(200_000):
        srv.pump()
        cli.collect()
        if srv.offload.stats.completed + srv.offload.stats.failed >= N_OPS:
            break
    dt = time.perf_counter() - t0
    return N_OPS / dt, srv.offload.stats.data_copies


def main() -> None:
    section("fig18: storage-path zero-copy (measured)")
    for size in (512, 4096, 16384):
        zc, zc_copies = _file_io_rate(True, size)
        cp, cp_copies = _file_io_rate(False, size)
        emit(f"fig18_size{size}", 1e6 / zc,
             f"zero_copy={zc:,.0f}/s copy={cp:,.0f}/s "
             f"gain={100 * (zc / cp - 1):.0f}% copies_eliminated={cp_copies}")
    section("fig23: offload-engine zero-copy (measured)")
    for size in (1024,):
        zc, _ = _offload_rate(True, size)
        cp, copies = _offload_rate(False, size)
        emit(f"fig23_size{size}", 1e6 / zc,
             f"zero_copy={zc:,.0f}/s copy={cp:,.0f}/s "
             f"gain={100 * (zc / cp - 1):.0f}% copies_in_copy_mode={copies}")


if __name__ == "__main__":
    main()
