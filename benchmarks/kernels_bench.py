"""Kernel micro-benchmarks (CPU wall time for the portable paths).

TPU wall times are not measurable here; these rows track the XLA-chunked
implementations' per-call cost on CPU (regression guard + relative scaling
with sequence length) and the kernels' FLOP counts.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, section, timeit
from repro.kernels.flash_attention.ops import flash_attention_xla
from repro.kernels.paged_attention.ref import paged_attention_ref
from repro.kernels.ssm_scan.ops import gla_scan_xla


def main() -> None:
    section("kernels: portable-path microbench (CPU)")
    key = jax.random.PRNGKey(0)
    for S in (256, 1024):
        B, H, KV, D = 1, 8, 2, 64
        q = jax.random.normal(key, (B, S, H, D), jnp.bfloat16)
        k = jax.random.normal(key, (B, S, KV, D), jnp.bfloat16)
        v = jax.random.normal(key, (B, S, KV, D), jnp.bfloat16)
        fn = jax.jit(lambda q, k, v: flash_attention_xla(
            q, k, v, causal=True, block_q=128, block_k=128))
        fn(q, k, v).block_until_ready()
        us = timeit(lambda: fn(q, k, v).block_until_ready(), n=5)
        flops = 4 * B * H * S * S * D / 2  # causal
        emit(f"kernel_flash_S{S}", us, f"{flops / us / 1e3:.1f} MFLOP/s-eq")
    for S in (256, 1024):
        B, H, K, V = 1, 4, 64, 64
        q = jax.random.normal(key, (B, H, S, K), jnp.float32) * 0.5
        kk = jax.random.normal(key, (B, H, S, K), jnp.float32) * 0.5
        vv = jax.random.normal(key, (B, H, S, V), jnp.float32)
        w = -jnp.ones((B, H, S, K)) * 0.01
        fn = jax.jit(lambda q, k, v, w: gla_scan_xla(q, k, v, w, chunk=128)[0])
        fn(q, kk, vv, w).block_until_ready()
        us = timeit(lambda: fn(q, kk, vv, w).block_until_ready(), n=5)
        emit(f"kernel_gla_S{S}", us, "chunked linear attention")
    # paged decode
    B, Hq, Hkv, D, P, page, maxp = 4, 8, 2, 64, 64, 64, 16
    q = jax.random.normal(key, (B, Hq, D), jnp.bfloat16)
    kp = jax.random.normal(key, (P, page, Hkv, D), jnp.bfloat16)
    vp = jax.random.normal(key, (P, page, Hkv, D), jnp.bfloat16)
    bt = jax.random.randint(key, (B, maxp), 0, P, jnp.int32)
    sl = jnp.full((B,), maxp * page, jnp.int32)
    fn = jax.jit(paged_attention_ref)
    fn(q, kp, vp, bt, sl).block_until_ready()
    us = timeit(lambda: fn(q, kp, vp, bt, sl).block_until_ready(), n=5)
    emit("kernel_paged_decode", us, f"kv_len={maxp * page}")


if __name__ == "__main__":
    main()
