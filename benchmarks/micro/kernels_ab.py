"""Scalar vs vectorized kernel A/B: probe, decode, pack, checksum.

Each pair times the pure-Python per-item loop the data plane ran before
the vectorization PR (A) against the array-at-a-time kernel it runs now
(B), on identical inputs, and asserts the outputs agree before printing
the ratio.  Rows follow the repo-wide ``name,us_per_call,derived``
format so output diffs cleanly against ``benchmarks/run.py``.

Usage::

    python -m benchmarks.micro.kernels_ab            # default burst sizes
    python -m benchmarks.micro.kernels_ab 64 1024    # specific burst sizes

This is a local iteration tool, not a CI gate: absolute numbers are
host-dependent, only the A/B ratio on one host is meaningful.
"""

from __future__ import annotations

import os
import struct
import sys

# Mirror run.py: allow `python benchmarks/micro/kernels_ab.py` too.
_ROOT = os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
for _p in (_ROOT, os.path.join(_ROOT, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

import numpy as np

from benchmarks.common import emit, section, timeit
from repro.core import vector
from repro.core.cache_table import CacheTable

_HDR = struct.Struct("<I")


def _ab(name: str, n: int, scalar_fn, vector_fn, check=None) -> None:
    if check is not None:
        check()
    a = timeit(scalar_fn, n=n)
    b = timeit(vector_fn, n=n)
    emit(name, b, f"scalar {a:.2f}us -> vector {b:.2f}us ({a / b:.2f}x)")


def bench_probe(burst: int) -> None:
    """Cache-table probe: per-key lookup loop vs ``lookup_many``."""
    table = CacheTable(max_items=4 * burst)
    keys = [b"key-%06d" % i for i in range(burst)]
    for i, k in enumerate(keys):
        table.insert(k, i)

    want = list(range(burst))

    def scalar():
        return [table.lookup(k) for k in keys]

    def vectorized():
        return table.lookup_many(keys)

    def check():
        assert scalar() == want and vectorized() == want

    _ab(f"probe_{burst}", max(2000 // burst, 20), scalar, vectorized, check)


def bench_hash(burst: int) -> None:
    """Key hashing alone: per-key splitmix64 vs one mixed array."""
    keys = [b"key-%06d" % i for i in range(burst)]
    raw = [hash(k) & vector.MASK64 for k in keys]

    def scalar():
        return [vector.scalar_mix(r) for r in raw]

    def vectorized():
        return vector.hash_keys(keys)

    def check():
        assert scalar() == list(vector.hash_keys(keys))

    _ab(f"hash_{burst}", max(4000 // burst, 50), scalar, vectorized, check)


def bench_decode(burst: int, payload: int = 64) -> None:
    """Frame decode: greedy length-word walk vs uniform-stride proof."""
    msgs = [bytes([i & 0xFF]) * payload for i in range(burst)]
    blob = b"".join(_HDR.pack(len(m)) + m for m in msgs)

    def scalar():
        out, off, total = [], 0, len(blob)
        while off + 4 <= total:
            ln = _HDR.unpack_from(blob, off)[0]
            if off + 4 + ln > total:
                break
            out.append(blob[off + 4:off + 4 + ln])
            off += 4 + ln
        return out

    def vectorized():
        got = vector.uniform_stride(blob, 4)
        assert got is not None
        n, stride, ln = got
        a = np.frombuffer(blob, dtype=np.uint8,
                          count=n * stride).reshape(n, stride)
        return a[:, 4:]   # columnar payload view, zero per-frame Python

    def check():
        assert scalar() == [bytes(r) for r in vectorized()]

    _ab(f"decode_{burst}", max(2000 // burst, 20), scalar, vectorized, check)


def bench_pack(burst: int, payload: int = 64) -> None:
    """Frame encode: 2n-fragment join vs batch header scatter."""
    msgs = [bytes([i & 0xFF]) * payload for i in range(burst)]

    def scalar():
        return b"".join(_HDR.pack(len(m)) + m for m in msgs)

    def vectorized():
        return vector.pack_frames(msgs)

    def check():
        assert scalar() == bytes(vectorized())

    _ab(f"pack_{burst}", max(2000 // burst, 20), scalar, vectorized, check)


def bench_checksum(nbytes: int) -> None:
    """Writev integrity checksum: per-word Python fold vs one numpy pass."""
    data = np.random.default_rng(7).integers(
        0, 256, size=nbytes, dtype=np.uint8).tobytes()

    def scalar():
        return vector.checksum64_scalar(data)

    def vectorized():
        return vector.checksum64(data)

    def check():
        assert scalar() == vectorized()

    _ab(f"checksum_{nbytes}B", max(200_000 // nbytes, 5),
        scalar, vectorized, check)


def main() -> None:
    bursts = [int(a) for a in sys.argv[1:]] or [32, 256, 2048]
    section("kernel A/B: scalar loop vs array-at-a-time (same inputs)")
    for n in bursts:
        bench_probe(n)
    for n in bursts:
        bench_hash(n)
    for n in bursts:
        bench_decode(n)
    for n in bursts:
        bench_pack(n)
    for n in bursts:
        bench_checksum(n * 64)


if __name__ == "__main__":
    main()
