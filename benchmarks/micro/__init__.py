"""Micro A/B harnesses: scalar reference loops vs vectorized kernels.

Not part of the CI gates — these exist for fast local iteration on the
array-at-a-time kernels in ``repro.core.vector`` without paying for a
full cluster benchmark run.  ``python -m benchmarks.micro.kernels_ab``.
"""
