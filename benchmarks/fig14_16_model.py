"""Figs 4/5/14/15/16/19/20/21: calibrated testbed model outputs.

These figures depend on BF-2 / NVMe / 100GbE hardware the container lacks;
the calibrated queueing model (repro.core.simulate — constants cited to the
paper) reproduces the paper's numbers.  Each row prints model output next
to the paper's reported anchor so the reproduction error is visible.
"""

from __future__ import annotations

from benchmarks.common import emit, section
from repro.core import simulate as sim


def main() -> None:
    section("fig14a: read throughput vs host CPU (model)")
    anchors = {
        "tcp+windows-files": (390, 10.7), "tcp+dds-files": (580, 6.5),
        "dds-offload": (730, 0.0),
    }
    for sol in (sim.baseline_tcp_ntfs_read(), sim.dds_frontend_read(),
                sim.dds_offload_read()):
        tgt, cores = anchors[sol.name]
        op = sol.evaluate(tgt)
        emit(f"fig14a_{sol.name}", op.p50_us,
             f"kiops={op.kiops:.0f} host_cores={op.host_cores:.1f} "
             f"(paper {tgt}K@{cores})")

    section("fig14b: write throughput vs host CPU (model)")
    for sol, tgt in ((sim.baseline_write(), 210), (sim.dds_frontend_write(), 290)):
        op = sol.evaluate(tgt)
        emit(f"fig14b_{sol.name}", op.p50_us,
             f"kiops={op.kiops:.0f} host_cores={op.host_cores:.1f}")

    section("fig15: latency at load (model; paper anchors in parens)")
    for sol, tgt, paper in ((sim.baseline_tcp_ntfs_read(), 390, "11 ms p50"),
                            (sim.dds_frontend_read(), 580, "~1.8 ms"),
                            (sim.dds_offload_read(), 730, "780 us"),
                            (sim.baseline_write(), 210, "48 ms p99"),
                            (sim.dds_frontend_write(), 290, "3 ms p99")):
        op = sol.evaluate(tgt)
        emit(f"fig15_{sol.name}", op.p50_us,
             f"p50={op.p50_us / 1e3:.2f}ms p99={op.p99_us / 1e3:.2f}ms "
             f"(paper {paper})")

    section("fig16: ten-solution comparison at peak (model)")
    for sol in sim.detailed_comparison():
        op = sol.evaluate(sol.peak_kiops())
        emit(f"fig16_{sol.name}", op.p50_us,
             f"peak={op.kiops:.0f}K host_cores={op.host_cores:.1f} "
             f"p50={op.p50_us / 1e3:.2f}ms p99={op.p99_us / 1e3:.2f}ms")

    section("fig4/19/20: echo latency by responder (model)")
    for size in (64, 1024, 16384):
        host = sim.echo_latency_us(size, "host")
        linux = sim.echo_latency_us(size, "dpu-linux")
        tldk = sim.echo_latency_us(size, "dpu-tldk")
        emit(f"fig19_echo_{size}B", tldk,
             f"host={host:.1f}us dpu_linux={linux:.1f}us dpu_tldk={tldk:.1f}us "
             f"(tldk {linux / tldk:.1f}x better than linux-on-dpu; "
             f"{host / tldk:.1f}x vs host)")

    section("fig5: FASTER RMW host vs DPU (model)")
    for threads in (1, 4, 8, 16):
        h = sim.faster_rmw_kops(threads, "host")
        d = sim.faster_rmw_kops(threads, "dpu")
        emit(f"fig5_rmw_t{threads}", 0.0,
             f"host={h:.0f}K dpu={d:.0f}K slowdown={h / d:.1f}x")

    section("fig21: traffic director scaling (model)")
    for cores in (1, 2, 4, 8):
        emit(f"fig21_cores{cores}", 0.0,
             f"{sim.director_bandwidth_gbps(cores):.1f} Gbps")

    section("fig24-26: production integrations (model)")
    for sol, tgt in ((sim.hyperscale_page_server(False), 90),
                     (sim.hyperscale_page_server(True), 160),
                     (sim.faster_kv(False), 340),
                     (sim.faster_kv(True), 970)):
        op = sol.evaluate(tgt)
        emit(f"fig24_26_{sol.name}", op.p50_us,
             f"kiops={op.kiops:.0f} host_cores={op.host_cores:.1f} "
             f"p50={op.p50_us / 1e3:.2f}ms p99={op.p99_us / 1e3:.2f}ms")


if __name__ == "__main__":
    main()
