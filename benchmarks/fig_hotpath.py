"""Hot-path ops/sec: the simulated data plane at CPython line rate.

DDS's argument (§4-§6) is that request throughput is won by deleting
per-request overhead — batching, zero-copy, O(1) bookkeeping.  This
benchmark applies the same standard to the simulator itself: it drives a
4-shard cluster with pipelined, batched clients issuing small offloaded
reads and measures

  * **wall-clock requests/sec** of the whole request/response hot path
    (director ingress -> offload engine -> pool -> indirect packets ->
    client reassembly), and
  * **modeled µs/request** (the paper-calibrated service time, which must
    NOT change when the simulator gets faster).

Results go to ``BENCH_hotpath.json`` in the repo root.  Because wall-clock
numbers are machine-dependent, every measurement is **calibrated**: a fixed
pure-Python reference loop is timed alongside the workload, and committed
numbers are rescaled by the ratio of reference speeds before any gate is
applied.  The JSON keeps three sections:

  ``baseline``  — the pre-overhaul hot path, recorded once with
                  ``--record-baseline`` before the zero-copy overhaul
                  (PR 2) landed;
  ``current``   — the overhauled hot path, recorded with
                  ``--record-current``;
  ``last_run``  — whatever this invocation measured (always rewritten).

Gates:

  * full mode asserts >= ``FULL_SPEEDUP_GATE`` (2.0x) calibrated ops/sec
    over the recorded baseline;
  * ``--smoke`` (CI fast lane) runs a reduced config and fails on a >30%
    calibrated regression vs the recorded ``current`` numbers;
  * both modes assert the zero-copy invariant (``data_copies == 0``) and
    that every read was served.
"""

from __future__ import annotations

import gc
import json
import os
import struct
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.common import emit, section  # noqa: E402
from repro.core.client import ClusterClient  # noqa: E402
from repro.core.dds_server import ServerConfig  # noqa: E402
from repro.distributed.cluster import DDSCluster  # noqa: E402

JSON_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_hotpath.json")

FULL_SPEEDUP_GATE = 2.0      # acceptance: overhaul >= 2x the pre-PR path
SMOKE_REGRESSION_GATE = 0.70  # CI: fail below 70% of recorded current

CONFIGS = {
    "full": dict(shards=4, clients=4, files_per_shard=8, rounds=16,
                 reads_per_round=256, read_size=128),
    "smoke": dict(shards=4, clients=2, files_per_shard=4, rounds=6,
                  reads_per_round=64, read_size=128),
}


def calibrate(iters: int = 200_000) -> float:
    """Reference ops/sec of a fixed pure-Python loop (machine-speed proxy).

    The loop mixes the primitives the hot path leans on (struct packing,
    dict traffic, bytes slicing) so the ratio between two machines tracks
    how the workload itself would scale.
    """
    pack = struct.Struct("<QII").pack
    blob = bytes(range(256)) * 8
    t0 = time.perf_counter()
    d: dict[int, bytes] = {}
    for i in range(iters):
        d[i & 1023] = blob[i & 255 : (i & 255) + 64]
        pack(i, i & 0xFFFF, 64)
    dt = time.perf_counter() - t0
    return iters / dt


def run_workload(cfg: dict) -> dict:
    """Drive the pipelined read workload; return measured + modeled rates."""
    # Small cache table / device: setup is untimed but repeated per rep.
    cluster = DDSCluster(num_shards=cfg["shards"],
                         config=ServerConfig(device_capacity=1 << 26,
                                             cache_items=1 << 11))
    files = [cluster.create_file(f"hot{i}")
             for i in range(cfg["shards"] * cfg["files_per_shard"])]
    file_span = 1 << 16
    for i, f in enumerate(files):
        cluster.write_sync(f, 0, bytes([i & 0xFF]) * file_span)

    clients = [ClusterClient(cluster) for _ in range(cfg["clients"])]
    total = cfg["rounds"] * cfg["reads_per_round"]
    rsize = cfg["read_size"]
    max_off = file_span - rsize

    modeled_before = cluster.makespan_s()
    gc.collect()
    gc.disable()   # keep collector pauses out of the timed region
    t0 = time.perf_counter()
    issued = 0
    poll_style = hasattr(clients[0], "poll")   # post-overhaul drain API
    for r in range(cfg["rounds"]):
        # one batched message per shard per client, pipelined behind the
        # previous round (flush, don't wait)
        per_client = [[] for _ in clients]
        for k in range(cfg["reads_per_round"]):
            f = files[(issued + k) % len(files)]
            off = ((issued + k) * 977) % max_off
            per_client[(issued + k) % len(clients)].append((f, off, rsize))
        issued += cfg["reads_per_round"]
        for cli, reads in zip(clients, per_client):
            if hasattr(cli, "read_many"):          # post-overhaul burst API
                cli.read_many(reads)
            else:                                  # pre-PR client: per-call
                for f, off, n in reads:
                    cli.read(f, off, n)
        for cli in clients:
            cli.flush()
        if poll_style:
            # one cluster step per round; every client drains only its own
            # demuxed flows
            cluster.pump()
            for cli in clients:
                cli.poll()
        else:
            for cli in clients:                    # pre-PR: each client must
                cli.pump()                         # re-step the whole cluster
    # drain: responses stream back through each client's demuxed flow
    for _ in range(1_000_000):
        if sum(c.stats.responses for c in clients) >= total:
            break
        if poll_style:
            work = cluster.pump() + sum(c.poll() for c in clients)
        else:
            work = sum(c.pump() for c in clients)
        if work == 0:
            for srv in cluster.servers:
                srv.device.drain()
    elapsed = time.perf_counter() - t0
    gc.enable()

    got = sum(c.stats.responses for c in clients)
    assert got == total, f"served {got}/{total} reads"
    copies = sum(s.offload.stats.data_copies for s in cluster.servers)
    assert copies == 0, f"zero-copy invariant violated: {copies} data copies"
    offloaded = sum(s.offload.stats.completed for s in cluster.servers)
    modeled_s = cluster.makespan_s() - modeled_before
    return {
        "requests": total,
        "wall_s": elapsed,
        "ops_per_s": total / elapsed,
        "modeled_us_per_req": modeled_s / total * 1e6,
        "offloaded_frac": offloaded / total,
    }


def load_json() -> dict:
    if os.path.exists(JSON_PATH):
        with open(JSON_PATH) as fh:
            return json.load(fh)
    return {"schema": 1, "configs": CONFIGS}


def save_json(doc: dict) -> None:
    with open(JSON_PATH, "w") as fh:
        json.dump(doc, fh, indent=2, sort_keys=True)
        fh.write("\n")


def main() -> None:
    argv = sys.argv[1:]
    smoke = ("--smoke" in argv
             or os.environ.get("DDS_BENCH_SMOKE", "0") == "1")
    record = ("baseline" if "--record-baseline" in argv else
              "current" if "--record-current" in argv else None)
    mode = "smoke" if smoke else "full"
    cfg = CONFIGS[mode]

    section(f"hot path ({mode}: {cfg['shards']} shards, {cfg['clients']} "
            f"clients, {cfg['rounds']}x{cfg['reads_per_round']} pipelined reads)")
    # Shared machines are noisy: take the best workload rep (max-of-N
    # approximates an unloaded machine) and pair it with the FASTEST
    # calibration observed across the run — the least-throttled estimate of
    # this machine's speed, which makes the normalized number conservative.
    reps = 2 if smoke else 3
    calib, res = 0.0, None
    for _ in range(reps):
        calib = max(calib, calibrate())
        r = run_workload(cfg)
        if res is None or r["ops_per_s"] > res["ops_per_s"]:
            res = r
    calib = max(calib, calibrate())
    emit(f"hotpath_{mode}", 1e6 / res["ops_per_s"],
         f"tput={res['ops_per_s']:.0f}op/s "
         f"modeled={res['modeled_us_per_req']:.2f}us/req "
         f"offload={res['offloaded_frac']:.2f}")

    doc = load_json()
    doc["configs"] = CONFIGS
    res = {**res, "config": cfg}   # pin the workload the numbers came from
    entry = {"calibration_ops_per_s": calib, mode: res}
    if record:
        doc.setdefault(record, {})["calibration_ops_per_s"] = calib
        doc[record][mode] = res
        print(f"# recorded {mode} measurement into '{record}'")
    doc["last_run"] = {"mode": mode, **entry}
    base, cur = doc.get("baseline", {}), doc.get("current", {})
    if base.get("full") and cur.get("full"):
        # normalized = ops per reference-op; ratio is machine-independent
        b = base["full"]["ops_per_s"] / base["calibration_ops_per_s"]
        c = cur["full"]["ops_per_s"] / cur["calibration_ops_per_s"]
        doc["speedup_full_calibrated"] = round(c / b, 3)
        doc["speedup_full_raw"] = round(cur["full"]["ops_per_s"]
                                        / base["full"]["ops_per_s"], 3)
    save_json(doc)

    def gate_ref(section: dict, which: str):
        """Recorded numbers are only comparable on the SAME workload."""
        ref = section.get(which)
        if ref and ref.get("config") != cfg:
            print(f"# recorded {which} numbers used a different workload "
                  f"config; gate skipped — re-record with the new config")
            return None
        return ref

    failures = []
    if not smoke and not record:
        base = doc.get("baseline", {})
        ref = gate_ref(base, "full")
        if ref:
            # rescale the committed baseline to THIS machine's speed
            scale = calib / base["calibration_ops_per_s"]
            target = ref["ops_per_s"] * scale * FULL_SPEEDUP_GATE
            ok = res["ops_per_s"] >= target
            print(f"# speedup vs baseline (calibrated): "
                  f"{res['ops_per_s'] / (ref['ops_per_s'] * scale):.2f}x "
                  f"(gate {FULL_SPEEDUP_GATE:.1f}x) -> {'OK' if ok else 'FAIL'}")
            if not ok:
                failures.append(
                    f"hot path below {FULL_SPEEDUP_GATE}x baseline: "
                    f"{res['ops_per_s']:.0f} < {target:.0f} op/s")
        else:
            print("# no recorded baseline; gate skipped")
    if smoke and not record:
        cur = doc.get("current", {})
        ref = gate_ref(cur, "smoke")
        if ref:
            scale = calib / cur["calibration_ops_per_s"]
            target = ref["ops_per_s"] * scale * SMOKE_REGRESSION_GATE
            ok = res["ops_per_s"] >= target
            print(f"# smoke vs recorded current (calibrated): "
                  f"{res['ops_per_s'] / (ref['ops_per_s'] * scale):.2f}x "
                  f"(gate {SMOKE_REGRESSION_GATE:.2f}x) -> "
                  f"{'OK' if ok else 'FAIL'}")
            if not ok:
                failures.append(
                    f"hot path regressed >30% vs recorded current: "
                    f"{res['ops_per_s']:.0f} < {target:.0f} op/s")
        else:
            print("# no recorded current numbers; gate skipped")
    if failures:
        raise RuntimeError("; ".join(failures))


if __name__ == "__main__":
    main()
