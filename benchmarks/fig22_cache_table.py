"""Fig 22: cache table insertion/lookup throughput (MEASURED).

Random cache items inserted by one writer (the file service role), then
looked up by 1..8 reader threads (traffic director / offload engine roles),
across item sizes.  Paper targets (Table 2): millions of inserts/s, tens of
millions of lookups/s on 8 Arm cores; CPython rates are GIL-bound but the
requirement shape (lookups scale with readers, inserts don't block reads)
is validated.
"""

from __future__ import annotations

import threading
import time

from benchmarks.common import emit, section
from repro.core.cache_table import CacheTable

N_ITEMS = 20_000
N_LOOKUPS = 50_000


def main() -> None:
    section("fig22: cache table (measured)")
    for item_size in (8, 64, 256):
        value = bytes(item_size)
        t = CacheTable(max_items=N_ITEMS)
        t0 = time.perf_counter()
        for i in range(N_ITEMS):
            t.insert(i, value)
        ins_rate = N_ITEMS / (time.perf_counter() - t0)
        emit(f"fig22_insert_sz{item_size}", 1e6 / ins_rate,
             f"{ins_rate:,.0f} inserts/s")
        for readers in (1, 4, 8):
            done = [0] * readers

            def reader(idx):
                n = N_LOOKUPS // readers
                for i in range(n):
                    t.lookup((i * 7919) % N_ITEMS)
                done[idx] = n

            t0 = time.perf_counter()
            ts = [threading.Thread(target=reader, args=(i,))
                  for i in range(readers)]
            for th in ts:
                th.start()
            for th in ts:
                th.join()
            rate = sum(done) / (time.perf_counter() - t0)
            emit(f"fig22_lookup_sz{item_size}_r{readers}", 1e6 / rate,
                 f"{rate:,.0f} lookups/s")


if __name__ == "__main__":
    main()
