"""Multi-tenant isolation: adversarial neighbor vs victim tail latency.

The DDS data plane multiplexes many compute-server tenants through one DPU
(§2: the whole point of consolidating storage software on the DPU is that
MANY hosts share it).  PR 6 threads first-class ``tenant_id`` through the
wire format and adds weighted-fair demux + token-bucket admission; this
benchmark measures what that buys under the classic noisy-neighbor
scenario, entirely in deterministic scheduler ticks.

Workload (open loop): a VICTIM tenant issues a modest stream of offloaded
GETs every tick; a HOSTILE tenant floods several times the cluster's device
service capacity from the same shards.  Three runs, same seed:

  * ``solo``       — the victim alone: its no-contention latency floor;
  * ``untenanted`` — both clients on the pre-tenancy default path (tenant
    0, no weights, no admission): the victim's GETs queue FIFO behind the
    flood, so its p99 rides the hostile backlog;
  * ``qos``        — victim and hostile carry distinct tenant ids and the
    servers run a tenancy profile (fair demux by default weight, the
    hostile tenant admission-limited by a token bucket).  Over-limit
    hostile requests shed EARLY with terminal ``E_SHED`` + retry-after
    hints, which the driver reaps like any real client must.

Gates (tick domain, within one process — machine-independent):

  * isolation: victim GET p99 in ``qos`` must be <= ``VICTIM_P99_GATE``
    (2.0x) its ``solo`` p99, while in ``untenanted`` the flood must
    actually have inflated it (>2x solo) — otherwise the scenario is not
    adversarial enough to prove anything;
  * no lost throughput: aggregate SERVED requests per tick in ``qos`` must
    stay >= ``TPUT_GATE`` (0.9x) of ``untenanted`` — isolation must come
    from scheduling, not from idling the device;
  * victim never sheds; the hostile tenant does (admission engaged);
  * determinism: two same-seed repetitions produce identical victim
    histograms and served/shed counts;
  * --smoke (CI): fails when the qos-run victim p99 regresses >30% vs the
    committed ``current``.

Results go to ``BENCH_tenancy.json`` (committed reference recorded with
``--record-baseline`` / ``--record-current``).
"""

from __future__ import annotations

import gc
import json
import os
import random
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.common import emit, section  # noqa: E402
from repro.core import wire  # noqa: E402
from repro.core.client import ClusterClient  # noqa: E402
from repro.core.dds_server import ServerConfig  # noqa: E402
from repro.core.qos import QoSProfile  # noqa: E402
from repro.distributed.cluster import DDSCluster  # noqa: E402

JSON_PATH = os.path.join(os.path.dirname(__file__), "..",
                         "BENCH_tenancy.json")

VICTIM_P99_GATE = 2.0     # qos victim p99 <= 2x its solo floor
INTERFERENCE_FLOOR = 2.0  # untenanted run must inflate victim p99 > 2x solo
TPUT_GATE = 0.9           # qos served/tick >= 0.9x untenanted served/tick
SMOKE_P99_REGRESSION = 1.3  # CI: fail when qos victim p99 grows >30%

# The offload ring is sized to hold the entire hostile backlog so all three
# runs serve from the SAME single path (the DPU device queue) and never spill
# to the host fallback: served/tick then compares like with like, and the
# untenanted run exposes the full FIFO queueing delay instead of hiding part
# of the flood on the host.  The bucket rate is set just under the device's
# per-shard service capacity (queue_depth per tick) net of the victim's
# per-shard arrival rate, so admission keeps the device busy (throughput
# gate) without letting a standing hostile backlog form (isolation gate).
CONFIGS = {
    "full": dict(shards=4, read_files=32, ticks=192, warmup=16,
                 victim_reads=16, hog_reads=96, read_size=256,
                 queue_depth=8, offload_ring=4096,
                 hog_rate=3.5, hog_burst=14.0, seed=11),
    "smoke": dict(shards=2, read_files=16, ticks=64, warmup=8,
                  victim_reads=8, hog_reads=48, read_size=256,
                  queue_depth=8, offload_ring=4096,
                  hog_rate=3.5, hog_burst=14.0, seed=11),
}


def percentile(hist: dict[int, int], p: float) -> int:
    n = sum(hist.values())
    if not n:
        return 0
    need = -(-n * p // 100)
    cum = 0
    d = 0
    for d in sorted(hist):
        cum += hist[d]
        if cum >= need:
            return d
    return d


def hist_doc(hist: dict[int, int]) -> dict:
    return {
        "counts": {str(d): hist[d] for d in sorted(hist)},
        "count": sum(hist.values()),
        "p50": percentile(hist, 50),
        "p95": percentile(hist, 95),
        "p99": percentile(hist, 99),
        "max": max(hist) if hist else 0,
    }


def run_workload(cfg: dict, mode: str) -> dict:
    """One adversarial-neighbor run; ``mode`` in solo/untenanted/qos."""
    assert mode in ("solo", "untenanted", "qos")
    qos = (QoSProfile(tenant_rates={2: cfg["hog_rate"]},
                      tenant_bursts={2: cfg["hog_burst"]})
           if mode == "qos" else QoSProfile())
    cluster = DDSCluster(num_shards=cfg["shards"],
                         config=ServerConfig(device_capacity=1 << 26,
                                             cache_items=1 << 11,
                                             offload_ring=cfg["offload_ring"],
                                             qos=qos))
    for srv in cluster.servers:
        srv.device.queue_depth = cfg["queue_depth"]
    span = 1 << 16
    # Balanced placement: the consistent-hash ring spreads files UNEVENLY
    # in small samples, and a shard whose victim+admitted arrival rate sits
    # above its service capacity builds an unbounded backlog that has
    # nothing to do with tenancy.  Keep creating files until every shard
    # owns read_files/shards of them, then draw each tick's reads
    # round-robin across shards so per-shard offered load is exact.
    quota = cfg["read_files"] // cfg["shards"]
    shard_files: list[list[int]] = [[] for _ in range(cfg["shards"])]
    i = 0
    while any(len(fl) < quota for fl in shard_files):
        f = cluster.create_file(f"ten-r{i}")
        i += 1
        fl = shard_files[cluster.shard_for_file(f)]
        if len(fl) < quota:
            fl.append(f)
            cluster.write_sync(f, 0, bytes([f & 0xFF]) * span)
    nsh = cfg["shards"]
    victim_tenant = 0 if mode == "untenanted" else 1
    hog_tenant = 0 if mode == "untenanted" else 2
    # FIXED ports: run-to-run identical flows => identical histograms.
    victim = ClusterClient(cluster, port=49000, tenant=victim_tenant)
    hog = (ClusterClient(cluster, port=49300, tenant=hog_tenant)
           if mode != "solo" else None)
    rng = random.Random(cfg["seed"])
    rsize = cfg["read_size"]
    hist: dict[int, int] = {}
    pending: dict[int, dict[int, int]] = {0: {}, 1: {}}  # ci -> rid -> stamp
    served = {"victim": 0, "hog": 0}
    sheds = {"victim": 0, "hog": 0}
    clients = [(0, "victim", victim)] + ([(1, "hog", hog)] if hog else [])
    tick = 0

    def harvest(ci: str, name: str, got: dict) -> None:
        nonlocal tick
        p = pending[ci]
        for rid, (status, _body) in got.items():
            stamp = p.pop(rid, None)
            if status == wire.E_SHED:
                sheds[name] += 1
                continue
            assert status == wire.E_OK, f"{name} rid {rid} status {status}"
            served[name] += 1
            if stamp is not None and stamp >= 0 and name == "victim":
                d = tick - stamp
                hist[d] = hist.get(d, 0) + 1

    total_ticks = cfg["warmup"] + cfg["ticks"]
    gc.collect()
    gc.disable()
    t0 = time.perf_counter()
    for t in range(total_ticks):
        stamp = tick if t >= cfg["warmup"] else -1
        for ci, name, cli in clients:
            n = cfg["victim_reads"] if name == "victim" else cfg["hog_reads"]
            ops = [("r", rng.choice(shard_files[j % nsh]),
                    rng.randrange(0, span - rsize), rsize)
                   for j in range(n)]
            for rid in cli.submit(ops):
                pending[ci][rid] = stamp
            cli.flush()
        cluster.pump()      # one scheduling step == one tick (open loop)
        tick += 1
        for ci, name, cli in clients:
            harvest(ci, name, cli.harvest())   # non-pumping drain
        if mode == "qos" and pending[1]:
            # Reap the hostile tenant's terminal sheds like a real client:
            # an admission-shed request never produces a wire response.
            harvest(1, "hog", hog.harvest(list(pending[1]), block=False))
    # Drain: arrivals stop; tick until every request is answered or shed.
    for _ in range(200_000):
        if not pending[0] and not pending[1]:
            break
        work = cluster.pump()
        tick += 1
        for ci, name, cli in clients:
            harvest(ci, name, cli.harvest())
        if work == 0:
            for srv in cluster.servers:
                srv.device.drain()
            for ci, name, cli in clients:
                if pending[ci]:
                    harvest(ci, name,
                            cli.harvest(list(pending[ci]), block=False))
    elapsed = time.perf_counter() - t0
    gc.enable()
    left = len(pending[0]) + len(pending[1])
    assert not left, f"{left} requests never completed ({mode})"
    assert sheds["victim"] == 0, f"victim shed {sheds['victim']} ({mode})"

    res = {
        "mode": mode,
        "ticks": tick,
        "wall_s": elapsed,
        "victim_get": hist_doc(hist),
        "victim_served": served["victim"],
        "hog_served": served["hog"],
        "hog_sheds": sheds["hog"],
        "served_total": served["victim"] + served["hog"],
        "served_per_tick": round((served["victim"] + served["hog"]) / tick,
                                 4),
    }
    stats = cluster.latency_stats()
    if mode == "qos":
        adm = stats["admission"]
        assert adm["granted"] + adm["shed"] == adm["offered"], \
            "admission conservation violated"
        res["admission"] = adm
        res["victim_server_dpu_p99"] = (
            cluster.tenant_latency(1, "dpu_read").percentile(99))
    return res


def load_json() -> dict:
    if os.path.exists(JSON_PATH):
        with open(JSON_PATH) as fh:
            return json.load(fh)
    return {"schema": 1, "configs": CONFIGS}


def save_json(doc: dict) -> None:
    with open(JSON_PATH, "w") as fh:
        json.dump(doc, fh, indent=2, sort_keys=True)
        fh.write("\n")


def main() -> None:
    argv = sys.argv[1:]
    smoke = ("--smoke" in argv
             or os.environ.get("DDS_BENCH_SMOKE", "0") == "1")
    record = ("baseline" if "--record-baseline" in argv else
              "current" if "--record-current" in argv else None)
    mode = "smoke" if smoke else "full"
    cfg = CONFIGS[mode]

    section(f"multi-tenant isolation ({mode}: {cfg['shards']} shards, "
            f"victim {cfg['victim_reads']} GET/tick vs hostile "
            f"{cfg['hog_reads']} GET/tick, bucket {cfg['hog_rate']}/tick, "
            f"{cfg['ticks']} ticks)")
    reps = []
    for _ in range(2):
        reps.append({m: run_workload(cfg, m)
                     for m in ("solo", "untenanted", "qos")})
    identical = all(
        r[m]["victim_get"]["counts"] == reps[0][m]["victim_get"]["counts"]
        and r[m]["served_total"] == reps[0][m]["served_total"]
        and r[m]["hog_sheds"] == reps[0][m]["hog_sheds"]
        for r in reps[1:] for m in r)
    res = reps[0]
    solo_p99 = max(res["solo"]["victim_get"]["p99"], 1)
    noisy_p99 = res["untenanted"]["victim_get"]["p99"]
    qos_p99 = res["qos"]["victim_get"]["p99"]
    emit(f"tenancy_{mode}", float(qos_p99),
         f"victim_p99 solo={solo_p99}t noisy={noisy_p99}t qos={qos_p99}t "
         f"tput={res['qos']['served_per_tick']}/t "
         f"(untenanted {res['untenanted']['served_per_tick']}/t) "
         f"hog_sheds={res['qos']['hog_sheds']} deterministic={identical}")

    doc = load_json()
    doc["configs"] = CONFIGS
    entry = {m: res[m] for m in res}
    entry["config"] = cfg
    entry["deterministic"] = identical
    if record:
        doc.setdefault(record, {})[mode] = entry
        print(f"# recorded {mode} measurement into '{record}'")
    doc["last_run"] = {"mode": mode, mode: entry}
    save_json(doc)

    failures = []
    if not identical:
        failures.append("two same-seed runs produced different results "
                        "(determinism gate)")
    if not record:
        # Within-run gates: solo/untenanted/qos all computed this process.
        ratio_iso = qos_p99 / solo_p99
        ok = ratio_iso <= VICTIM_P99_GATE
        print(f"# victim GET p99 under QoS: {qos_p99}t vs solo {solo_p99}t "
              f"({ratio_iso:.2f}x; gate <= {VICTIM_P99_GATE:.1f}x) -> "
              f"{'OK' if ok else 'FAIL'}")
        if not ok:
            failures.append(
                f"victim p99 not isolated: {qos_p99}t > "
                f"{VICTIM_P99_GATE:.1f}x solo ({solo_p99}t)")
        hurt = noisy_p99 / solo_p99
        ok = hurt > INTERFERENCE_FLOOR
        print(f"# untenanted interference: {noisy_p99}t = {hurt:.2f}x solo "
              f"(must exceed {INTERFERENCE_FLOOR:.1f}x to be adversarial) "
              f"-> {'OK' if ok else 'FAIL'}")
        if not ok:
            failures.append(
                f"workload not adversarial enough: untenanted victim p99 "
                f"only {hurt:.2f}x solo")
        tput = (res["qos"]["served_per_tick"]
                / res["untenanted"]["served_per_tick"])
        ok = tput >= TPUT_GATE
        print(f"# aggregate served/tick: qos {res['qos']['served_per_tick']}"
              f" vs untenanted {res['untenanted']['served_per_tick']} "
              f"({tput:.2f}x; gate >= {TPUT_GATE:.2f}x) -> "
              f"{'OK' if ok else 'FAIL'}")
        if not ok:
            failures.append(
                f"isolation bought with throughput: {tput:.2f}x < "
                f"{TPUT_GATE:.2f}x served/tick vs untenanted")
        if res["qos"]["hog_sheds"] == 0:
            failures.append("admission never engaged (hog_sheds == 0)")
    if smoke and not record:
        ref = doc.get("current", {}).get("smoke")
        if ref and ref.get("config") == cfg:
            limit = ref["qos"]["victim_get"]["p99"] * SMOKE_P99_REGRESSION
            ok = qos_p99 <= limit
            print(f"# smoke qos victim p99 vs recorded current: {qos_p99} "
                  f"vs {ref['qos']['victim_get']['p99']} ticks "
                  f"(limit {limit:.1f}) -> {'OK' if ok else 'FAIL'}")
            if not ok:
                failures.append(
                    f"qos victim p99 regressed >30% vs recorded current: "
                    f"{qos_p99} > {limit:.1f} ticks")
        else:
            print("# no recorded current smoke numbers; gate skipped")
    if failures:
        raise RuntimeError("; ".join(failures))


if __name__ == "__main__":
    main()
