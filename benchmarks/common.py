"""Benchmark helpers: CSV output in ``name,us_per_call,derived`` form."""

from __future__ import annotations

import sys
import time


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.3f},{derived}")
    sys.stdout.flush()


def section(title: str) -> None:
    print(f"# --- {title} ---")


def timeit(fn, *, n: int, warmup: int = 2) -> float:
    """Median-of-3 wall time per call in microseconds."""
    for _ in range(warmup):
        fn()
    best = []
    for _ in range(3):
        t0 = time.perf_counter()
        for _ in range(n):
            fn()
        best.append((time.perf_counter() - t0) / n * 1e6)
    best.sort()
    return best[1]
