"""§Roofline: per-cell roofline terms from the compiled dry-run.

Reads ``results/dryrun/*.json`` (written by ``repro.launch.dryrun``) and
prints the roofline table.  XLA's cost analysis counts a scan body ONCE, so
when reduced-layer records (``__L{n}`` suffix) exist for a cell, totals are
reconstructed by two-point extrapolation:

    body  = (f(2u) - f(u)) / u          (per layer-unit cost)
    total = f(u) - u*body + L*body

Terms (v5e, per chip): compute = FLOPs/197e12, memory = bytes/819e9,
collective = collective-bytes/50e9.  The bottleneck is the max term;
"mfu_bound" = (MODEL_FLOPS/chips)/197e12 / max-term — the roofline fraction
an ideal overlap would reach, which §Perf hill-climbs.
"""

from __future__ import annotations

import glob
import json
import os
import re
from collections import defaultdict

from benchmarks.common import emit, section

PEAK = 197e12
HBM = 819e9
ICI = 50e9

RESULTS = os.environ.get("DDS_DRYRUN_DIR", "results/dryrun")


def load_records(outdir: str = RESULTS) -> dict:
    recs = {}
    for path in sorted(glob.glob(os.path.join(outdir, "*.json"))):
        name = os.path.basename(path)[:-5]
        m = re.match(r"(.+)__(.+)__(single|multi)(?:__L(\d+))?$", name)
        if not m:
            continue
        arch, shape, mesh, layers = m.groups()
        with open(path) as f:
            recs[(arch, shape, mesh, int(layers) if layers else None)] = \
                json.load(f)
    return recs


def _full_layers(arch: str) -> int:
    from repro.configs import get_config
    return get_config(arch).num_layers


def _unit(arch: str) -> int:
    from repro.configs import get_config
    from repro.launch.dryrun import layer_unit
    return layer_unit(get_config(arch))


def extrapolate(recs: dict, arch: str, shape: str, mesh: str) -> dict | None:
    """Scan-aware totals from the __L{u} and __L{2u} records, else the
    full-config record as-is (flagged)."""
    u = _unit(arch)
    small = recs.get((arch, shape, mesh, u))
    big = recs.get((arch, shape, mesh, 2 * u))
    full = recs.get((arch, shape, mesh, None))
    if full is None or full.get("status") != "ok":
        return full
    L = _full_layers(arch)
    out = dict(full)
    if (small and big and small.get("status") == "ok"
            and big.get("status") == "ok"):
        for key in ("hlo_flops_per_chip", "hlo_bytes_per_chip",
                    "collective_bytes_per_chip"):
            body = (big[key] - small[key]) / u
            out[key] = max(full[key], small[key] - u * body + L * body)
        out["extrapolated"] = True
    else:
        out["extrapolated"] = False
    # COMPUTE: XLA counts scan bodies once even after layer extrapolation
    # (inner attention/GLA chunk scans), so the analytic MODEL_FLOPS is the
    # correct per-step compute; the HLO value is kept as a lower bound.
    # MEMORY/COLLECTIVE: the once-counted inner scans coincide with ideal
    # fused-kernel traffic (q/k/v read once), which is what a TPU Pallas
    # lowering does — the extrapolated per-layer totals are the estimate.
    per_chip_model = out["model_flops_global"] / out["nchips"]
    out["compute_s"] = max(out["hlo_flops_per_chip"], per_chip_model) / PEAK
    out["memory_s"] = out["hlo_bytes_per_chip"] / HBM
    out["collective_s"] = out["collective_bytes_per_chip"] / ICI
    terms = {"compute": out["compute_s"], "memory": out["memory_s"],
             "collective": out["collective_s"]}
    out["dominant"] = max(terms, key=terms.get)
    tstar = max(terms.values())
    out["mfu_bound"] = (per_chip_model / PEAK) / tstar if tstar else 0.0
    out["hlo_coverage"] = (out["hlo_flops_per_chip"] / per_chip_model
                           if per_chip_model else 0.0)
    return out


def fmt_s(x: float) -> str:
    if x >= 1:
        return f"{x:7.2f}s "
    if x >= 1e-3:
        return f"{x * 1e3:6.2f}ms"
    return f"{x * 1e6:6.1f}us"


def main() -> None:
    # Prefer the optimized sweep when present; the baseline table stays in
    # results/dryrun (EXPERIMENTS.md shows both).
    global RESULTS
    if (not os.environ.get("DDS_DRYRUN_DIR")
            and os.path.isdir("results/dryrun_opt")
            and glob.glob("results/dryrun_opt/*.json")):
        RESULTS = "results/dryrun_opt"
    recs = load_records(RESULTS)
    if not recs:
        print("# no dry-run records found; run python -m repro.launch.dryrun --all")
        return
    print(f"# source: {RESULTS}")
    section("roofline terms per (arch x shape), single-pod 16x16")
    cells = sorted({(a, s) for (a, s, m, l) in recs if m == "single"
                    and l is None})
    for arch, shape in cells:
        rec = extrapolate(recs, arch, shape, "single")
        if rec is None:
            continue
        if rec.get("status") == "skipped":
            emit(f"roofline_{arch}_{shape}", 0.0,
                 f"SKIPPED: {rec.get('reason', '')}")
            continue
        if rec.get("status") != "ok":
            emit(f"roofline_{arch}_{shape}", 0.0,
                 f"ERROR: {rec.get('error', '?')[:80]}")
            continue
        emit(f"roofline_{arch}_{shape}",
             max(rec["compute_s"], rec["memory_s"], rec["collective_s"]) * 1e6,
             f"compute={fmt_s(rec['compute_s'])} "
             f"memory={fmt_s(rec['memory_s'])} "
             f"collective={fmt_s(rec['collective_s'])} "
             f"dominant={rec['dominant']} "
             f"mfu_bound={rec['mfu_bound']:.3f} "
             f"hlo_cov={rec.get('hlo_coverage', 0):.2f} "
             f"extrap={rec.get('extrapolated', False)}")
    section("multi-pod (2x16x16) compile status")
    ok = sum(1 for (a, s, m, l), r in recs.items()
             if m == "multi" and l is None and r.get("status") == "ok")
    skip = sum(1 for (a, s, m, l), r in recs.items()
               if m == "multi" and l is None and r.get("status") == "skipped")
    err = sum(1 for (a, s, m, l), r in recs.items()
              if m == "multi" and l is None and r.get("status") == "error")
    emit("multi_pod_cells", 0.0, f"ok={ok} skipped={skip} errors={err}")


if __name__ == "__main__":
    main()
