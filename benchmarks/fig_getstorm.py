"""Cache-table-bound offloaded-GET storm: the vectorized data plane gate.

PR 8 rebuilds the inner loops of the three hottest structures — cuckoo
cache probing, wire frame decode/pack, and the checksummed writev path —
as array-at-a-time kernels over contiguous numpy backing stores.  This
benchmark is the workload those kernels are FOR: a high-hit-rate sharded-KV
GET storm where every request crosses

  batch decode (director) -> offload predicate (``lookup_many`` burst
  cuckoo probe) -> offload engine -> device priority read -> packetize ->
  client reassembly

and the per-request Python work, not the device, is the bottleneck.  Keys
are fixed-width (uniform frames — the vectorized structured-dtype decode
path) and Zipf-skewed (realistic reuse; the cache table serves virtually
everything after warmup).

Measurements per run:

  * **wall-clock GETs/sec** of the whole storm (calibrated: a fixed
    pure-Python reference loop is timed alongside and committed numbers
    are rescaled by reference-speed ratio before any gate),
  * **modeled µs/request** — the paper-calibrated service time, which must
    NOT drift when the simulator gets faster (<5% vs baseline),
  * **DPU-served fraction** — deterministic and ~1.0: the storm must stay
    on the offloaded path, and two same-seed reps must agree exactly.

Results go to ``BENCH_getstorm.json`` (baseline / current / last_run, as
in ``fig_hotpath``).  Gates:

  * full mode asserts >= ``FULL_SPEEDUP_GATE`` (2.0x) calibrated ops/sec
    over the recorded pre-PR baseline and <``DRIFT_GATE`` modeled drift;
  * ``--smoke`` (CI) fails on a >30% calibrated regression vs recorded
    ``current``;
  * both modes gate the DPU-served fraction and its determinism.
"""

from __future__ import annotations

import gc
import json
from dataclasses import fields
import os
import struct
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.common import emit, section  # noqa: E402
from repro.apps.kv_store import (KVClient, ShardedKVStore,  # noqa: E402
                                 decode_record, encode_get)
from repro.core import wire  # noqa: E402
from repro.core.dds_server import ServerConfig, drain_client_flow  # noqa: E402

JSON_PATH = os.path.join(os.path.dirname(__file__), "..",
                         "BENCH_getstorm.json")

FULL_SPEEDUP_GATE = 2.0       # acceptance: vectorized >= 2x the pre-PR path
SMOKE_REGRESSION_GATE = 0.70  # CI: fail below 70% of recorded current
DRIFT_GATE = 0.05             # modeled us/req must stay within 5% of baseline
DPU_FRAC_GATE = 0.95          # the storm must stay on the offloaded path

CONFIGS = {
    "full": dict(shards=4, clients=2, hot_keys=2048, zipf_a=1.15, rounds=6,
                 gets_per_round=3072, value_size=96),
    "smoke": dict(shards=2, clients=2, hot_keys=512, zipf_a=1.15, rounds=4,
                  gets_per_round=256, value_size=96),
}

ZIPF_SEED = 0x6E75F0


def calibrate(iters: int = 200_000) -> float:
    """Reference ops/sec of a fixed pure-Python loop (machine-speed proxy).

    Same loop as ``fig_hotpath``: struct packing, dict traffic and bytes
    slicing — the primitives the request path leans on — so the ratio
    between two machines tracks how the workload itself would scale.
    """
    pack = struct.Struct("<QII").pack
    blob = bytes(range(256)) * 8
    t0 = time.perf_counter()
    d: dict[int, bytes] = {}
    for i in range(iters):
        d[i & 1023] = blob[i & 255 : (i & 255) + 64]
        pack(i, i & 0xFFFF, 64)
    return iters / (time.perf_counter() - t0)


def _zipf_ranks(cfg: dict, total: int) -> list[int]:
    """Seeded skewed rank sequence, precomputed (untimed): the exact same
    key sequence every rep, every run, every machine."""
    rng = np.random.default_rng(ZIPF_SEED)
    return [(int(z) - 1) % cfg["hot_keys"]
            for z in rng.zipf(cfg["zipf_a"], size=total)]


def run_workload(cfg: dict) -> dict:
    """Drive the offloaded-GET storm; return measured + modeled rates."""
    kwargs = dict(device_capacity=1 << 26,
                  cache_items=max(1 << 11, 2 * cfg["hot_keys"]),
                  offload_ring=1024)
    # Array-at-a-time engines want deep pulls; the pre-PR tree (baseline
    # recording) has no burst knob — its engine pulls its fixed 64.
    if any(f.name == "offload_burst" for f in fields(ServerConfig)):
        kwargs["offload_burst"] = 128
    config = ServerConfig(**kwargs)
    store = ShardedKVStore(num_shards=cfg["shards"], config=config)
    cluster = store.cluster
    clients = [KVClient(store) for _ in range(cfg["clients"])]
    # Fixed-width keys: every GET frame has the same size, so a burst is a
    # UNIFORM batch — the regime the array-at-a-time decode kernels target.
    keys = [b"g%07d" % i for i in range(cfg["hot_keys"])]
    vsize = cfg["value_size"]

    # Untimed warm: PUT-ack every key (arms the DPU cache at write
    # completion), then one GET sweep to confirm the table serves them.
    res = clients[0].harvest(clients[0].submit(
        [("put", k, (k * (vsize // len(k) + 1))[:vsize]) for k in keys]))
    assert all(s == wire.E_OK for s, _ in res.values())
    res = clients[0].harvest(clients[0].submit([("get", k) for k in keys]))
    assert all(s == wire.E_OK for s, _ in res.values())
    for cli in clients:
        cli.net.run_until_idle()

    total = cfg["rounds"] * cfg["clients"] * cfg["gets_per_round"]
    ranks = _zipf_ranks(cfg, total)
    rk = iter(ranks)
    dpu_before = store.dpu_served_gets()
    ticks_before = cluster.clock.now
    # Modeled time = the devices' calibrated service model (base latency +
    # bytes/bandwidth).  The vectorization PR must make the SIMULATOR
    # faster without moving this number.
    modeled_before = sum(s.device.stats.modeled_busy_s
                         for s in cluster.servers)
    check = keys[ranks[0]]
    # Pre-encode the storm (untimed): every GET frame, routed to its shard,
    # batched per (round, client, shard).  The timed region then exercises
    # the DATA PLANE — batch framing, the wire, the engine's vectorized
    # probe/translate/submit path, device model, response reassembly — and
    # not per-op client bookkeeping (rid ledgers, latency stamps, replay
    # notes), which would otherwise dominate and hide what this PR changes.
    nsh = cfg["shards"]
    shard_of = [clients[0]._shard(k) for k in keys]
    rid = 1 << 32   # clear of every rid the warmup used
    plan = []
    for _ in range(cfg["rounds"]):
        per_client = []
        for _cli in clients:
            per_shard: list[list[bytes]] = [[] for _ in range(nsh)]
            for _ in range(cfg["gets_per_round"]):
                i = next(rk)
                per_shard[shard_of[i]].append(encode_get(rid, keys[i]))
                rid += 1
            per_client.append(per_shard)
        plan.append(per_client)
    resp: list[dict[int, tuple[int, bytes]]] = [{} for _ in clients]
    gc.collect()
    gc.disable()   # keep collector pauses out of the timed region
    t0 = time.perf_counter()
    for per_client in plan:
        need = 0
        for cli, per_shard in zip(clients, per_client):
            conns = cli.net.conns
            for s, frames in enumerate(per_shard):
                if frames:
                    conn = conns[s]
                    conn._pending.extend(frames)
                    conn.flush()   # ONE batch-framed packet per shard
                    need += len(frames)
        spins = 0
        while need:
            cluster.pump()
            for ci, cli in enumerate(clients):
                r = resp[ci]
                for conn in cli.net.conns:
                    before = len(r)
                    drain_client_flow(conn.server.director, conn._resp_flow,
                                      conn._rx, r, None)
                    need -= len(r) - before
            spins += 1
            assert spins < 100_000, "storm round failed to drain"
    elapsed = time.perf_counter() - t0
    gc.enable()
    got = sum(1 for r in resp for s, _ in r.values() if s == wire.E_OK)

    assert got == total, f"served {got}/{total} GETs"
    dpu = store.dpu_served_gets() - dpu_before
    modeled_s = sum(s.device.stats.modeled_busy_s
                    for s in cluster.servers) - modeled_before
    # Spot-check payload integrity once (untimed): the storm must return
    # the record bytes the warmup wrote.
    status, body = clients[0].harvest(
        clients[0].submit([("get", check)])).popitem()[1]
    assert status == wire.E_OK
    assert decode_record(body)[1] == (check * (vsize // len(check) + 1))[:vsize]
    return {
        "requests": total,
        "wall_s": elapsed,
        "ops_per_s": total / elapsed,
        "modeled_us_per_req": modeled_s / total * 1e6,
        "dpu_frac": dpu / total,
        "ticks": cluster.clock.now - ticks_before,
    }


def load_json() -> dict:
    if os.path.exists(JSON_PATH):
        with open(JSON_PATH) as fh:
            return json.load(fh)
    return {"schema": 1, "configs": CONFIGS}


def save_json(doc: dict) -> None:
    with open(JSON_PATH, "w") as fh:
        json.dump(doc, fh, indent=2, sort_keys=True)
        fh.write("\n")


def main() -> None:
    argv = sys.argv[1:]
    smoke = ("--smoke" in argv
             or os.environ.get("DDS_BENCH_SMOKE", "0") == "1")
    record = ("baseline" if "--record-baseline" in argv else
              "current" if "--record-current" in argv else None)
    mode = "smoke" if smoke else "full"
    cfg = CONFIGS[mode]

    section(f"offloaded-GET storm ({mode}: {cfg['shards']} shards, "
            f"{cfg['clients']} clients, {cfg['rounds']}x"
            f"{cfg['gets_per_round']} Zipf(a={cfg['zipf_a']}) GETs over "
            f"{cfg['hot_keys']} keys)")
    # Best-of-N workload reps, each PAIRED with calibrations taken
    # immediately around it (machine speed drifts between reps on shared
    # hosts, so an unpaired max-ops/max-calib quotient mixes two moments);
    # the kept rep is the one with the best calibrated score.  The reps
    # double as the determinism sample — the tick count and DPU-served
    # count must agree exactly across same-seed runs, wall-clock noise
    # notwithstanding.
    reps = 2 if smoke else 5
    calib, res, fingerprints = 0.0, None, set()
    c_prev = calibrate()
    for _ in range(reps):
        r = run_workload(cfg)
        c_next = calibrate()
        c_here = max(c_prev, c_next)   # this rep's machine-speed estimate
        c_prev = c_next
        fingerprints.add((r["ticks"], r["dpu_frac"],
                          round(r["modeled_us_per_req"], 9)))
        if res is None or (r["ops_per_s"] / c_here
                           > res["ops_per_s"] / calib):
            res, calib = r, c_here
    deterministic = len(fingerprints) == 1
    emit(f"getstorm_{mode}", 1e6 / res["ops_per_s"],
         f"tput={res['ops_per_s']:.0f}op/s "
         f"modeled={res['modeled_us_per_req']:.2f}us/req "
         f"dpu_frac={res['dpu_frac']:.3f} deterministic={deterministic}")

    doc = load_json()
    doc["configs"] = CONFIGS
    res = {**res, "config": cfg, "deterministic": deterministic}
    entry = {"calibration_ops_per_s": calib, mode: res}
    if record:
        doc.setdefault(record, {})["calibration_ops_per_s"] = calib
        doc[record][mode] = res
        print(f"# recorded {mode} measurement into '{record}'")
    doc["last_run"] = {"mode": mode, **entry}
    base, cur = doc.get("baseline", {}), doc.get("current", {})
    if base.get("full") and cur.get("full"):
        b = base["full"]["ops_per_s"] / base["calibration_ops_per_s"]
        c = cur["full"]["ops_per_s"] / cur["calibration_ops_per_s"]
        doc["speedup_full_calibrated"] = round(c / b, 3)
        doc["speedup_full_raw"] = round(cur["full"]["ops_per_s"]
                                        / base["full"]["ops_per_s"], 3)
    save_json(doc)

    def gate_ref(sec: dict, which: str):
        """Recorded numbers are only comparable on the SAME workload."""
        ref = sec.get(which)
        if ref and ref.get("config") != cfg:
            print(f"# recorded {which} numbers used a different workload "
                  f"config; gate skipped — re-record with the new config")
            return None
        return ref

    failures = []
    if res["dpu_frac"] < DPU_FRAC_GATE:
        failures.append(f"storm left the offloaded path: dpu_frac "
                        f"{res['dpu_frac']:.3f} < {DPU_FRAC_GATE}")
    if not deterministic:
        failures.append("same-seed reps diverged (ticks / dpu_frac / "
                        "modeled time) — determinism gate")
    if not smoke and not record:
        base = doc.get("baseline", {})
        ref = gate_ref(base, "full")
        if ref:
            scale = calib / base["calibration_ops_per_s"]
            target = ref["ops_per_s"] * scale * FULL_SPEEDUP_GATE
            ok = res["ops_per_s"] >= target
            print(f"# speedup vs baseline (calibrated): "
                  f"{res['ops_per_s'] / (ref['ops_per_s'] * scale):.2f}x "
                  f"(gate {FULL_SPEEDUP_GATE:.1f}x) -> {'OK' if ok else 'FAIL'}")
            if not ok:
                failures.append(
                    f"GET storm below {FULL_SPEEDUP_GATE}x baseline: "
                    f"{res['ops_per_s']:.0f} < {target:.0f} op/s")
            drift = (abs(res["modeled_us_per_req"] - ref["modeled_us_per_req"])
                     / max(ref["modeled_us_per_req"], 1e-12))
            ok = drift < DRIFT_GATE
            print(f"# modeled-time drift vs baseline: {drift * 100:.2f}% "
                  f"(gate <{DRIFT_GATE * 100:.0f}%) -> "
                  f"{'OK' if ok else 'FAIL'}")
            if not ok:
                failures.append(
                    f"modeled us/req drifted {drift * 100:.1f}% from "
                    f"baseline (vectorization must not change the model)")
        else:
            print("# no recorded baseline; speedup/drift gates skipped")
    if smoke and not record:
        cur = doc.get("current", {})
        ref = gate_ref(cur, "smoke")
        if ref:
            scale = calib / cur["calibration_ops_per_s"]
            target = ref["ops_per_s"] * scale * SMOKE_REGRESSION_GATE
            ok = res["ops_per_s"] >= target
            print(f"# smoke vs recorded current (calibrated): "
                  f"{res['ops_per_s'] / (ref['ops_per_s'] * scale):.2f}x "
                  f"(gate {SMOKE_REGRESSION_GATE:.2f}x) -> "
                  f"{'OK' if ok else 'FAIL'}")
            if not ok:
                failures.append(
                    f"GET storm regressed >30% vs recorded current: "
                    f"{res['ops_per_s']:.0f} < {target:.0f} op/s")
        else:
            print("# no recorded current numbers; gate skipped")
    if failures:
        raise RuntimeError("; ".join(failures))


if __name__ == "__main__":
    main()
