"""Elastic resharding gate: grow the cluster mid-run, double the throughput.

PR 10 adds online ring membership: ``ShardedKVStore.add_shard`` streams the
joiner's keys source -> destination over the host wire while the cluster
keeps serving, dual-routes writes during the handoff (the ack holds until
the destination holds the bytes), and flips ownership atomically with an
epoch bump that in-flight requests ride via the PR 7 fence + replay.  This
benchmark holds that machinery to the paper's scale-out economics: storage
you can GROW under load, without a maintenance window, without losing a
byte.

One scenario: a Zipf-skewed closed-loop GET/overwrite workload runs on N
shards; mid-run the cluster doubles to 2N, one ``add_shard`` at a time,
with the workload never pausing.  Everything is measured in deterministic
TICKS of the shared cluster clock:

  * **throughput doubles** — steady ops/tick after growth must reach
    >= ``TPUT_GATE`` (1.8x) the N-shard rate: the joiners take real load,
    they are not decorative ring entries.
  * **zero lost acked writes** — every acked PUT is byte-compared on every
    subsequent read AND in a final full-ledger sweep, across all the
    migrations and epoch bumps.  Hard gate, any mode.
  * **bounded growth window** — each add_shard reaches its ownership flip
    within ``FLIP_TICK_BUDGET`` ticks of starting; the whole doubling
    (including cleanup drains) fits ``GROW_TICK_BUDGET`` ticks per joiner.
  * **bounded p99 blip** — rounds racing a live migration may exceed the
    steady-state p99 by at most ``BLIP_SLACK`` ticks (held dual-route
    acks, fence replays), and post-growth rounds must be FASTER than
    steady state (that is the point of growing).

Two same-seed runs must produce identical round-tick traces, reshard
events and ledgers (determinism gate).  Results go to
``BENCH_reshard.json``; ``--smoke`` (CI) runs a reduced config and fails
on a >30% tick regression vs the committed ``current`` numbers.
"""

from __future__ import annotations

import gc
import json
import os
import struct
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.common import emit, section  # noqa: E402
from repro.apps.kv_store import KVClient, ShardedKVStore, decode_record  # noqa: E402
from repro.core import wire  # noqa: E402
from repro.core.dds_server import ServerConfig  # noqa: E402

JSON_PATH = os.path.join(os.path.dirname(__file__), "..",
                         "BENCH_reshard.json")

TPUT_GATE = 1.8         # post-growth steady ops/tick >= 1.8x pre-growth
BLIP_SLACK = 48         # growth-round allowance beyond the steady p99
FLIP_TICK_BUDGET = 160  # add_shard -> ownership flip, per joiner
GROW_TICK_BUDGET = 360  # add_shard -> migration retired, per joiner
SMOKE_REGRESSION = 1.3  # CI: fail when ticks grow >30% vs recorded current

CONFIGS = {
    "full": dict(shards=8, grow_to=16, clients=2, hot_keys=1024, zipf_a=1.03,
                 pre_rounds=10, post_rounds=10, max_grow_rounds=160,
                 gets=512, overwrites=128, value_size=64, queue_depth=4),
    "smoke": dict(shards=4, grow_to=8, clients=2, hot_keys=512, zipf_a=1.03,
                  pre_rounds=6, post_rounds=6, max_grow_rounds=120,
                  gets=384, overwrites=96, value_size=64, queue_depth=4),
}

ZIPF_SEED = 0x6E517A


def calibrate(iters: int = 200_000) -> float:
    """Reference ops/sec of a fixed pure-Python loop (machine-speed proxy)."""
    pack = struct.Struct("<QII").pack
    blob = bytes(range(256)) * 8
    t0 = time.perf_counter()
    d: dict[int, bytes] = {}
    for i in range(iters):
        d[i & 1023] = blob[i & 255 : (i & 255) + 64]
        pack(i, i & 0xFFFF, 64)
    return iters / (time.perf_counter() - t0)


def percentile(vals: list[int], p: float) -> int:
    """Exact percentile of a small integer sample (nearest-rank)."""
    if not vals:
        return 0
    s = sorted(vals)
    return s[min(len(s) - 1, -(-len(s) * int(p) // 100) - 1)]


def _zipf_ranks(cfg: dict, total: int) -> list[int]:
    """Seeded skewed rank sequence, precomputed (untimed): the exact same
    key sequence every rep, every run, every machine."""
    rng = np.random.default_rng(ZIPF_SEED)
    return [(int(z) - 1) % cfg["hot_keys"]
            for z in rng.zipf(cfg["zipf_a"], size=total)]


def _value(key: bytes, rnd: int, size: int) -> bytes:
    """Round-stamped value, a function of (key, round) ONLY — two clients
    overwriting the same key in the same round agree on the bytes, so the
    acked ledger is unambiguous."""
    base = key + b"#%05d#" % rnd
    return (base * (size // len(base) + 1))[:size]


def run_reshard_workload(cfg: dict) -> dict:
    """Closed-loop Zipf GET/overwrite rounds; double the shard count
    mid-run, one live migration at a time, and keep score in ticks."""
    config = ServerConfig(device_capacity=1 << 26, cache_items=1 << 14,
                          dedup_cache=1 << 10)
    store = ShardedKVStore(num_shards=cfg["shards"], config=config,
                           elastic=True)
    cluster = store.cluster
    qd = cfg["queue_depth"]
    for srv in cluster.servers:
        # Bounded per-poll completion budget: rounds are limited by device
        # service rate, so ops/tick tracks how many shards share the load
        # — the regime the 1.8x growth gate is about.
        srv.device.queue_depth = qd
    clients = [KVClient(store) for _ in range(cfg["clients"])]
    vsize = cfg["value_size"]
    hot = [b"grow-%04d" % i for i in range(cfg["hot_keys"])]

    # Untimed warm: PUT-ack every key (seeds the acked ledger + DPU cache).
    acked: dict[bytes, bytes] = {}
    rids = clients[0].submit([("put", k, _value(k, -1, vsize)) for k in hot])
    res = clients[0].harvest(rids)
    assert all(s == wire.E_OK for s, _ in res.values())
    for k in hot:
        acked[k] = _value(k, -1, vsize)
    res = clients[0].harvest(clients[0].submit([("get", k) for k in hot]))
    assert all(s == wire.E_OK for s, _ in res.values())
    for cli in clients:
        cli.net.run_until_idle()

    per_round = cfg["gets"] + cfg["overwrites"]
    budget = (cfg["pre_rounds"] + cfg["post_rounds"]
              + cfg["max_grow_rounds"])
    ranks = iter(_zipf_ranks(cfg, budget * cfg["clients"] * per_round))
    lost = 0
    total = 0
    round_ticks: list[int] = []
    grow_spans: list[dict] = []   # per-joiner: add->flip and add->retired

    def one_round(r: int) -> None:
        nonlocal lost, total
        t_start = cluster.clock.now
        # GETs and overwrites go out in ONE pipelined batch per client —
        # a second serialized submit/harvest phase would add a fixed
        # per-round latency floor that masks the shard-parallel service
        # time the growth gate is about.  A GET racing this round's
        # overwrite of the same key may see either generation; both are
        # exact, because _value is a function of (key, round) only and
        # both clients stamp identical bytes.
        owr = [[hot[next(ranks)] for _ in range(cfg["overwrites"])]
               for _ in clients]
        this_gen = {k for ks in owr for k in ks}
        meta = []
        for cli, oks in zip(clients, owr):
            gks = [hot[next(ranks)] for _ in range(cfg["gets"])]
            ops = [("get", k) for k in gks]
            ops += [("put", k, _value(k, r, vsize)) for k in oks]
            meta.append((cli, gks, oks, cli.submit(ops)))
        for cli, gks, oks, rids in meta:
            res = cli.harvest(rids)
            for k, rid in zip(gks, rids):
                status, body = res[rid]
                if status != wire.E_OK:
                    lost += 1
                    continue
                val = decode_record(body)[1]
                if val != acked[k] and not (
                        k in this_gen and val == _value(k, r, vsize)):
                    lost += 1
            for k, rid in zip(oks, rids[len(gks):]):
                if res[rid][0] == wire.E_OK:
                    acked[k] = _value(k, r, vsize)
                else:
                    lost += 1
        # No run_until_idle here: with a live migration the cluster never
        # goes idle (the resharder keeps the pump busy through its
        # cleanup grace), and the whole point is that the workload NEVER
        # pauses for it — a round ends when its harvests complete.
        total += cfg["clients"] * per_round
        round_ticks.append(cluster.clock.now - t_start)

    gc.collect()
    gc.disable()   # keep collector pauses out of the timed region
    t0 = time.perf_counter()
    rnd = 0
    for _ in range(cfg["pre_rounds"]):
        one_round(rnd)
        rnd += 1
    pre_ticks = round_ticks[:]
    grow_first = rnd

    # Mid-run growth: one live migration at a time, workload never pauses.
    pending = cfg["grow_to"] - cfg["shards"]
    span = None
    while pending or cluster.resharder is not None:
        if cluster.resharder is None and pending:
            new = store.add_shard()
            cluster.servers[new].device.queue_depth = qd
            span = {"joiner": new, "add_tick": cluster.clock.now,
                    "flip_tick": None, "retired_tick": None}
            grow_spans.append(span)
            pending -= 1
        one_round(rnd)
        rnd += 1
        if span is not None and span["flip_tick"] is None \
                and cluster.reshard_events \
                and cluster.reshard_events[-1]["kind"] == f"add:{span['joiner']}":
            span["flip_tick"] = cluster.reshard_events[-1]["tick"]
        if span is not None and cluster.resharder is None:
            span["retired_tick"] = cluster.clock.now
            span = None
        if rnd - grow_first > cfg["max_grow_rounds"]:
            raise RuntimeError("growth never finished within "
                               f"{cfg['max_grow_rounds']} rounds")
    grow_ticks_list = round_ticks[grow_first:]

    post_first = rnd
    for _ in range(cfg["post_rounds"]):
        one_round(rnd)
        rnd += 1
    post_ticks = round_ticks[post_first:]

    # Final sweep: every byte ever acked must be readable on the grown ring.
    sweep = clients[0].submit([("get", k) for k in hot])
    res = clients[0].harvest(sweep)
    for k, rid in zip(hot, sweep):
        status, body = res[rid]
        if status != wire.E_OK or decode_record(body)[1] != acked[k]:
            lost += 1
    elapsed = time.perf_counter() - t0
    gc.enable()

    ops_round = cfg["clients"] * per_round
    reshard = cluster.latency_stats().get("resharding", {})
    return {
        "requests": total,
        "ticks": cluster.clock.now,
        "wall_s": elapsed,
        "ops_per_s": total / elapsed,
        "lost_acked": lost,
        "round_ticks": round_ticks,
        "pre_ops_per_tick": (len(pre_ticks) * ops_round
                             / max(sum(pre_ticks), 1)),
        "post_ops_per_tick": (len(post_ticks) * ops_round
                              / max(sum(post_ticks), 1)),
        "pre_p99": percentile(pre_ticks, 99),
        "grow_p99": percentile(grow_ticks_list, 99),
        "post_p99": percentile(post_ticks, 99),
        "grow_rounds": len(grow_ticks_list),
        "grow_spans": grow_spans,
        "flip_ticks_max": max(s["flip_tick"] - s["add_tick"]
                              for s in grow_spans),
        "grow_ticks_max": max(s["retired_tick"] - s["add_tick"]
                              for s in grow_spans),
        "keys_migrated": reshard.get("totals", {}).get("keys_migrated", 0),
        "dual_routed": reshard.get("totals", {}).get("dual_routed", 0),
        "reshard_events": reshard.get("events", []),
        "final_shards": len(cluster.servers),
    }


def load_json() -> dict:
    if os.path.exists(JSON_PATH):
        with open(JSON_PATH) as fh:
            return json.load(fh)
    return {"schema": 1, "configs": CONFIGS}


def save_json(doc: dict) -> None:
    with open(JSON_PATH, "w") as fh:
        json.dump(doc, fh, indent=2, sort_keys=True)
        fh.write("\n")


def main() -> None:
    argv = sys.argv[1:]
    smoke = ("--smoke" in argv
             or os.environ.get("DDS_BENCH_SMOKE", "0") == "1")
    record = ("current" if "--record-current" in argv else None)
    mode = "smoke" if smoke else "full"
    cfg = CONFIGS[mode]

    section(f"elastic resharding ({mode}: {cfg['shards']} -> "
            f"{cfg['grow_to']} shards mid-run, {cfg['clients']} clients, "
            f"Zipf a={cfg['zipf_a']} over {cfg['hot_keys']} keys)")
    # Two same-seed runs (determinism gate); wall-clock is paired with
    # surrounding calibrations for the report line only — every gate below
    # lives in the deterministic tick domain.
    c1 = calibrate()
    res = run_reshard_workload(cfg)
    res2 = run_reshard_workload(cfg)
    c2 = calibrate()
    calib = max(c1, c2)
    identical = all(res[k] == res2[k] for k in
                    ("round_ticks", "reshard_events", "lost_acked",
                     "ticks", "requests", "keys_migrated"))
    ratio = res["post_ops_per_tick"] / max(res["pre_ops_per_tick"], 1e-9)
    emit(f"reshard_{mode}", ratio,
         f"growth={ratio:.2f}x lost_acked={res['lost_acked']} "
         f"migrated={res['keys_migrated']} dual_routed={res['dual_routed']} "
         f"grow_p99={res['grow_p99']}t flip_max={res['flip_ticks_max']}t "
         f"deterministic={identical} tput={res['ops_per_s']:.0f}op/s")

    doc = load_json()
    doc["configs"] = CONFIGS
    res_out = {k: v for k, v in res.items() if k != "round_ticks"}
    res_out["config"] = cfg
    res_out["deterministic"] = identical
    res_out["growth_ratio"] = round(ratio, 3)
    entry = {"calibration_ops_per_s": calib, mode: res_out}
    if record:
        doc.setdefault("current", {})["calibration_ops_per_s"] = calib
        doc["current"][mode] = res_out
        print(f"# recorded {mode} measurement into 'current'")
    doc["last_run"] = {"mode": mode, **entry}
    save_json(doc)

    failures = []
    if res["lost_acked"]:
        failures.append(f"{res['lost_acked']} acknowledged writes lost or "
                        f"stale across the growth (gate: zero)")
    if not identical:
        failures.append("two same-seed runs diverged (round ticks, reshard "
                        "events or ledger) — determinism gate")
    ok = ratio >= TPUT_GATE
    print(f"# steady ops/tick, {cfg['grow_to']} vs {cfg['shards']} shards: "
          f"{res['post_ops_per_tick']:.2f} vs {res['pre_ops_per_tick']:.2f} "
          f"({ratio:.2f}x; gate {TPUT_GATE:.2f}x) -> "
          f"{'OK' if ok else 'FAIL'}")
    if not ok:
        failures.append(f"growth did not pay: {ratio:.2f}x < "
                        f"{TPUT_GATE:.2f}x the pre-growth ops/tick")
    blip_limit = res["pre_p99"] + BLIP_SLACK
    ok = res["grow_p99"] <= blip_limit
    print(f"# growth-round p99: {res['grow_p99']}t (steady p99 "
          f"{res['pre_p99']}t + slack {BLIP_SLACK}t = limit {blip_limit}t) "
          f"-> {'OK' if ok else 'FAIL'}")
    if not ok:
        failures.append(f"migration blip unbounded: {res['grow_p99']} > "
                        f"{blip_limit} ticks")
    ok = res["post_p99"] <= res["pre_p99"]
    print(f"# post-growth round p99: {res['post_p99']}t vs pre "
          f"{res['pre_p99']}t -> {'OK' if ok else 'FAIL'}")
    if not ok:
        failures.append(f"post-growth p99 did not improve: "
                        f"{res['post_p99']} > {res['pre_p99']} ticks")
    ok = res["flip_ticks_max"] <= FLIP_TICK_BUDGET
    print(f"# slowest add->flip: {res['flip_ticks_max']}t "
          f"(budget {FLIP_TICK_BUDGET}t) -> {'OK' if ok else 'FAIL'}")
    if not ok:
        failures.append(f"ownership flip too slow: {res['flip_ticks_max']} "
                        f"> {FLIP_TICK_BUDGET} ticks")
    ok = res["grow_ticks_max"] <= GROW_TICK_BUDGET
    print(f"# slowest add->retired: {res['grow_ticks_max']}t "
          f"(budget {GROW_TICK_BUDGET}t) -> {'OK' if ok else 'FAIL'}")
    if not ok:
        failures.append(f"migration drain too slow: {res['grow_ticks_max']} "
                        f"> {GROW_TICK_BUDGET} ticks")
    if smoke and not record:
        ref = doc.get("current", {}).get("smoke")
        if ref and ref.get("config") == cfg:
            for key in ("grow_p99", "pre_p99", "grow_ticks_max"):
                limit = max(ref[key], 1) * SMOKE_REGRESSION
                if res[key] > limit:
                    failures.append(
                        f"{key} regressed >30% vs recorded current: "
                        f"{res[key]} > {limit:.1f} ticks")
            print(f"# smoke vs recorded current: grow p99 {res['grow_p99']}t "
                  f"vs {ref['grow_p99']}t, growth {ratio:.2f}x "
                  f"vs {ref['growth_ratio']:.2f}x")
        else:
            print("# no comparable recorded current numbers; "
                  "smoke regression gate skipped")
    if failures:
        raise RuntimeError("; ".join(failures))


if __name__ == "__main__":
    main()
