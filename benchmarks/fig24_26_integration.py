"""Figs 24-26 functional counterpart (MEASURED offload behaviour).

The latency/CPU numbers for the production integrations are modeled
(fig14_16_model.py); what IS measurable here is the part DDS actually
contributes — the offload RATIO and correctness of the partial-offload
policy under a realistic access mix:

  * page server: replay pages (host writes), then serve GetPage@LSN where
    a fraction of requests ask for LSNs newer than the cache (must fall to
    the host) and the rest offload;
  * FASTER-style KV: uniform GETs over flushed records (DPU) vs tail
    records (host), as in §9.2 where "most requests are serviced by
    IDevice".
"""

from __future__ import annotations

import time

from benchmarks.common import emit, section
from repro.core.dds_server import DDSClient, encode_batch
from repro.storage.pagestore import KVStoreServer, PageStore

N_PAGES = 64
N_GETS = 400


def page_server() -> None:
    ps = PageStore(page_size=1024, num_pages=N_PAGES * 2)
    for p in range(N_PAGES):
        ps.replay(p, lsn=100, payload=f"page-{p}".encode())
    cli = DDSClient(ps.server)
    t0 = time.perf_counter()
    rid = 0
    for i in range(N_GETS):
        rid += 1
        if i % 10 == 0:
            # 10%: LSN newer than the cache -> host path (partial offload);
            # dedicated page range, since the host read invalidates the page
            # until the next log replay re-caches it (§9.1 semantics).
            page, lsn = N_PAGES - 1 - (i // 10) % 8, 150
        else:
            page, lsn = (i * 13) % (N_PAGES - 8), 100
        cli._send(encode_batch([PageStore.encode_get(rid, page, lsn)]))
        cli.wait(rid)
    dt = time.perf_counter() - t0
    st = ps.server.offload.stats
    emit("fig24_pageserver", dt / N_GETS * 1e6,
         f"dpu_served={st.completed} host_served={ps.host_served} "
         f"offload_ratio={st.completed / N_GETS:.2f} "
         f"host_cpu_s={ps.server.host_cpu_busy_s:.4f}")


def kv_server() -> None:
    kv = KVStoreServer()
    for i in range(256):
        kv.upsert(f"k{i}".encode(), f"v{i}".encode() * 4)
    kv.flush()                              # all 256 now DPU-servable
    for i in range(16):
        kv.upsert(f"hot{i}".encode(), b"tail")   # 16 host-resident keys
    cli = DDSClient(kv.server)
    t0 = time.perf_counter()
    rid = 0
    for i in range(N_GETS):
        rid += 1
        key = (f"hot{i % 16}" if i % 16 == 0 else f"k{(i * 7) % 256}").encode()
        cli._send(encode_batch([KVStoreServer.encode_get(rid, key)]))
        cli.wait(rid)
    dt = time.perf_counter() - t0
    st = kv.server.offload.stats
    emit("fig25_26_kv", dt / N_GETS * 1e6,
         f"dpu_served={st.completed} "
         f"offload_ratio={st.completed / N_GETS:.2f} "
         f"host_cpu_s={kv.server.host_cpu_busy_s:.4f}")


def main() -> None:
    section("fig24-26: integration offload ratios (measured)")
    page_server()
    kv_server()


if __name__ == "__main__":
    main()
