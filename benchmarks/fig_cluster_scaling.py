"""Cluster scale-out: throughput and DPU-served fraction vs. shard count.

Runs the §9.2 KV workload (host-path PUTs, offloaded GETs) against clusters
of 1/2/4/8 DDS storage servers behind consistent-hash key sharding, using
the batched, pipelined cluster client.  Reported throughput uses MODELED
service time (per-packet DPU cost + per-request host CPU cost, §5.3/§8),
with the busiest shard bounding the cluster — wall-clock of the Python
simulation itself is meaningless here.

Output rows (benchmarks.common CSV convention):

    cluster_put_shardsN,us_per_op,tput=...op/s
    cluster_get_shardsN,us_per_op,tput=...op/s dpu_frac=...

Smoke mode (``--smoke`` or DDS_BENCH_SMOKE=1) shrinks the key count; the
shape of the curve — monotonically rising aggregate throughput 1 -> 4 and a
nonzero offloaded fraction — must survive smoke mode (CI asserts it).
"""

from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.common import emit, section  # noqa: E402
from repro.apps.kv_store import KVClient, ShardedKVStore  # noqa: E402

SHARD_COUNTS = (1, 2, 4, 8)


def run_workload(num_shards: int, n_keys: int, get_rounds: int) -> dict:
    store = ShardedKVStore(num_shards=num_shards)
    client = KVClient(store)
    keys = [f"user:{i:05d}".encode() for i in range(n_keys)]
    value = b"x" * 256

    # Phase 1: PUTs (host path; Cache() arms the DPU for every record).
    put_rids = [client.put(k, value) for k in keys]
    client.flush()
    client.run_until_idle()
    for r in put_rids:
        client.wait_put(r)
    put_busy = store.cluster.stats().per_shard_busy_s
    put_makespan = max(put_busy)

    # Phase 2: pipelined GET rounds (offloaded; zero host CPU on hits).
    get_rids = []
    for _ in range(get_rounds):
        get_rids += [client.get(k) for k in keys]
        client.flush()                 # next batch pipelined behind this one
    client.run_until_idle()
    for r in get_rids:
        status, _ = client.net.wait(r)
        assert status == 0
    total_busy = store.cluster.stats().per_shard_busy_s
    total_makespan = max(total_busy)

    n_puts, n_gets = len(put_rids), len(get_rids)
    # GET-phase critical path: subtract per shard BEFORE taking the max —
    # the PUT-busiest and overall-busiest shard need not be the same one.
    get_makespan = max(max(t - p for t, p in zip(total_busy, put_busy)), 1e-9)
    dpu_frac = store.dpu_served_gets() / max(n_gets, 1)
    return {
        "shards": num_shards,
        "puts": n_puts,
        "gets": n_gets,
        "put_tput": n_puts / max(put_makespan, 1e-9),
        "get_tput": n_gets / get_makespan,
        "agg_tput": (n_puts + n_gets) / max(total_makespan, 1e-9),
        "dpu_frac": dpu_frac,
    }


def main() -> None:
    smoke = ("--smoke" in sys.argv
             or os.environ.get("DDS_BENCH_SMOKE", "0") == "1")
    n_keys = 96 if smoke else 384
    get_rounds = 2 if smoke else 4
    section(f"cluster scaling (KV workload, {n_keys} keys, "
            f"{get_rounds} GET rounds{', smoke' if smoke else ''})")
    results = []
    for n in SHARD_COUNTS:
        r = run_workload(n, n_keys, get_rounds)
        results.append(r)
        emit(f"cluster_put_shards{n}", 1e6 / r["put_tput"],
             f"tput={r['put_tput']:.0f}op/s")
        emit(f"cluster_get_shards{n}", 1e6 / r["get_tput"],
             f"tput={r['get_tput']:.0f}op/s dpu_frac={r['dpu_frac']:.2f}")
        emit(f"cluster_agg_shards{n}", 1e6 / r["agg_tput"],
             f"tput={r['agg_tput']:.0f}op/s")
    by_shards = {r["shards"]: r for r in results}
    mono = (by_shards[1]["agg_tput"] < by_shards[2]["agg_tput"]
            < by_shards[4]["agg_tput"])
    offloaded = all(r["dpu_frac"] > 0 for r in results)
    print(f"# aggregate throughput monotonic 1->2->4 shards: {mono}")
    print(f"# DPU-served GET fraction nonzero on every size: {offloaded}")
    if not (mono and offloaded):
        # RuntimeError (not SystemExit) so run.py counts this as ONE failed
        # module and still runs the rest of the benchmark suite.
        raise RuntimeError("cluster scaling benchmark failed its invariants")


if __name__ == "__main__":
    main()
